"""Quickstart: train a diffusion-LM denoiser, then sample with ERA-Solver.

End-to-end driver (deliverable b): data pipeline -> training loop ->
checkpoint -> ERA-Solver sampling -> quality report against the known data
distribution.

    PYTHONPATH=src python examples/quickstart.py                  # ~1 min CPU
    PYTHONPATH=src python examples/quickstart.py --preset 100m \
        --steps 300                                               # the real run

The ``100m`` preset is a ~100M-parameter qwen2-family denoiser — the
configuration used for the paper-style experiments on real hardware; the
default ``tiny`` preset keeps CPU runtime to about a minute.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ERAConfig, get_solver, linear_schedule
from repro.data import DataConfig, GaussianMixtureLatents
from repro.models import build_model
from repro.models.diffusion import DiffusionLM
from repro.training import (
    OptimizerConfig,
    make_diffusion_train_step,
    train,
)

PRESETS = {
    # (base config, overrides, seq, batch)
    "tiny": ("qwen2-1.5b", dict(smoke=True), 16, 16),
    "100m": ("qwen2-1.5b", dict(), 64, 32),  # trimmed below to ~100M
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="artifacts/quickstart")
    args = ap.parse_args()

    base, kw, seq, batch = PRESETS[args.preset]
    cfg = get_config(base, **kw)
    if args.preset == "100m":
        cfg = cfg.with_(
            num_layers=10, d_model=768, num_heads=12, num_kv_heads=4,
            d_ff=2048, vocab_size=4096, vocab_pad_multiple=64, head_dim=64,
            dtype=jnp.float32, remat=False,
        )
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(jax.random.PRNGKey(args.seed))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"denoiser: {cfg.name} ({n/1e6:.1f}M params), seq={seq}")

    sched = linear_schedule()
    dc = DataConfig(vocab_size=1, seq_len=seq, batch_size=batch,
                    kind="diffusion", d_model=cfg.d_model, num_modes=4,
                    seed=args.seed)
    data = GaussianMixtureLatents(dc)
    step = make_diffusion_train_step(
        dlm,
        OptimizerConfig(lr=2e-3, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps),
        sched,
    )
    res = train(step, params, data.batches(), args.steps,
                ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 2, 50))
    print(f"trained: loss {res.history[0]['loss']:.4f} -> "
          f"{res.history[-1]['loss']:.4f}")

    # --- sample with ERA-Solver (the paper's Algorithm 1) ---
    xT = jax.random.normal(jax.random.PRNGKey(args.seed + 1),
                           (64, seq, cfg.d_model))
    out = get_solver("era")(
        dlm.eps_fn(res.params), xT, sched,
        ERAConfig(nfe=args.nfe, k=3, lam=5.0, error_norm="mean"),
    )
    mu, var = data.moments()
    got = np.asarray(out.x0.reshape(-1, cfg.d_model))
    mu_err = float(np.linalg.norm(got.mean(0) - mu) / np.linalg.norm(mu))
    var_err = float(np.linalg.norm(got.var(0) - var) / np.linalg.norm(var))
    print(f"ERA-Solver @ NFE={args.nfe}: mean-err {mu_err:.3f}, "
          f"var-err {var_err:.3f} (vs data moments)")
    print("delta_eps history: "
          f"{np.asarray(out.aux['delta_eps_history'])[3:].round(3).tolist()}")

    # --- the same model behind the batched serving engine ---
    from repro.serving import BatchedSampler, SampleRequest

    engine = BatchedSampler(dlm, sched, batch_buckets=(1, 8))
    futs = [
        engine.submit_with_future(
            SampleRequest(batch=1, seq_len=seq, nfe=args.nfe, seed=s)
        )[1]
        for s in range(4)
    ]
    engine.drain(res.params)
    results = [f.result() for f in futs]
    lat = sum(r.latency_s for r in results) / len(results)
    print(f"batched engine: {len(results)} requests fused to "
          f"batch {results[0].padded_batch}, "
          f"mean latency {lat * 1e3:.1f} ms "
          f"({len(engine.compile_cache())} compiled bucket)")


if __name__ == "__main__":
    main()
