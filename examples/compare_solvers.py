"""Compare every registered solver on one pretrained denoiser — the
paper's Tables 1-3 in miniature, printed as a table.

    PYTHONPATH=src python examples/compare_solvers.py --nfes 5 10 20
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    ERAConfig,
    default_config,
    get_solver,
    linear_schedule,
    solver_names,
)
from repro.data import DataConfig, GaussianMixtureLatents
from repro.models import build_model
from repro.models.diffusion import DiffusionLM
from repro.training import OptimizerConfig, make_diffusion_train_step, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nfes", type=int, nargs="+", default=[5, 10, 20])
    ap.add_argument("--train-steps", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("llama3.2-1b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    sched = linear_schedule()
    dc = DataConfig(vocab_size=1, seq_len=8, batch_size=16, kind="diffusion",
                    d_model=cfg.d_model, num_modes=2, seed=3)
    step = make_diffusion_train_step(
        dlm, OptimizerConfig(lr=2e-3, total_steps=args.train_steps), sched
    )
    res = train(step, dlm.init(jax.random.PRNGKey(args.seed)),
                GaussianMixtureLatents(dc).batches(), args.train_steps,
                log_every=1000, print_fn=lambda s: None)
    eps_fn = dlm.eps_fn(res.params)

    xT = jax.random.normal(jax.random.PRNGKey(7), (64, 8, cfg.d_model))
    ref = get_solver("ddim")(eps_fn, xT, sched,
                             default_config("ddim", nfe=600)).x0

    print(f"{'solver':22s} " + " ".join(f"NFE={n:<3d}" for n in args.nfes))
    for name in solver_names():
        row = []
        for nfe in args.nfes:
            conf = (ERAConfig(nfe=nfe, k=3, error_norm="mean")
                    if name == "era" else default_config(name, nfe=nfe))
            try:
                x0 = get_solver(name)(eps_fn, xT, sched, conf).x0
                row.append(f"{float(jnp.sqrt(jnp.mean((x0-ref)**2))):.4f}")
            except ValueError as e:  # nfe < k etc.
                row.append("  n/a ")
        print(f"{name:22s} " + " ".join(f"{r:>7s}" for r in row))
    print("\n(RMSE to a 600-step DDIM reference on the same trained model; "
          "lower is better)")


if __name__ == "__main__":
    main()
