"""Batched AR serving across architecture families — prefill + KV-cache
decode on dense / MoE / SSM / hybrid / VLM / audio backbones, plus a
sliding-window (ring-buffer) long-context decode.

    PYTHONPATH=src python examples/serve_multi_arch.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import frontend_features
from repro.models import build_model
from repro.serving import Engine, ServeConfig

ARCHS = [
    "llama3.2-1b",        # dense GQA
    "mixtral-8x7b",       # MoE + SWA
    "deepseek-v2-lite-16b",  # MLA compressed cache
    "xlstm-350m",         # recurrent state
    "hymba-1.5b",         # hybrid attn+mamba, meta tokens
    "paligemma-3b",       # VLM (stub patches)
    "whisper-base",       # enc-dec audio (stub frames)
]


def main() -> None:
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    B, prompt_len, gen = 2, 12, 16

    for name in ARCHS:
        cfg = get_config(name, smoke=True)
        m = build_model(cfg)
        params = m.init(key)
        eng = Engine(m, ServeConfig(max_len=256))
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, prompt_len)), jnp.int32
        )
        extras = {}
        if cfg.family == "vlm":
            extras["patches"] = jnp.asarray(frontend_features(
                rng, B, cfg.frontend.num_positions, cfg.d_model))
        if cfg.family == "audio":
            extras["frames"] = jnp.asarray(frontend_features(
                rng, B, cfg.frontend.num_positions, cfg.d_model))
        t0 = time.perf_counter()
        toks = eng.generate(params, prompts, gen, extras=extras, key=key)
        dt = time.perf_counter() - t0
        print(f"{name:22s} [{cfg.family:6s}] -> {tuple(toks.shape)} "
              f"in {dt:5.1f}s   head: {toks[0][:6].tolist()}")

    # long-context: ring-buffer decode far beyond the window
    cfg = get_config("llama3.2-1b", smoke=True)
    m = build_model(cfg)
    params = m.init(key)
    eng = Engine(m, ServeConfig(max_len=4096, window_override=32))
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 100)), jnp.int32)
    toks = eng.generate(params, prompts, 64, key=key)
    print(f"{'llama3.2-1b (SWA-32)':22s} [ring  ] -> {tuple(toks.shape)} "
          "(decoded 64 tokens through a 32-slot ring cache)")


if __name__ == "__main__":
    main()
