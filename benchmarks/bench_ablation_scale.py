"""Paper Figs. 5/6: the error-aware power scale (delta_eps / lambda) vs
constant power scales.  Claim: the adaptive scale matches or beats the best
constant, without per-dataset tuning."""

import jax

from benchmarks import common as C


def run() -> None:
    mix = C.AnalyticMixture()
    noisy = mix.noisy(0.03)
    xT = jax.random.normal(jax.random.PRNGKey(0), (256, 16))
    ref = C.reference_solution(mix.eps, xT)

    for nfe in (10, 20):
        for const in (0.5, 1.0, 2.0, 4.0, 8.0):
            x0 = C.solve(noisy, xT, "era", nfe, k=3,
                         selection="const", const_power=const,
                         error_norm="mean")
            C.emit(f"fig56/const{const}/nfe{nfe}", 0.0,
                   f"rmse={C.rmse(x0, ref):.5f}")
        for lam in (2.0, 5.0, 15.0):
            x0 = C.solve(noisy, xT, "era", nfe, k=3, lam=lam,
                         selection="ers", error_norm="mean")
            C.emit(f"fig56/adaptive-lam{lam}/nfe{nfe}", 0.0,
                   f"rmse={C.rmse(x0, ref):.5f}")


if __name__ == "__main__":
    run()
