"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Output contract: ``name,us_per_call,derived`` CSV lines.
"""

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        bench_ablation_ers,
        bench_ablation_scale,
        bench_coldstart,
        bench_error_measure,
        bench_renoise_error,
        bench_serving,
        bench_solver_quality,
        bench_walltime,
        roofline,
    )

    suites = {
        "solver_quality": bench_solver_quality.run,   # Tables 1/2/3/6
        "ablation_ers": bench_ablation_ers.run,       # Tables 4/5
        "ablation_scale": bench_ablation_scale.run,   # Figs 5/6
        "error_measure": bench_error_measure.run,     # Fig 3
        "renoise_error": bench_renoise_error.run,     # Appendix C
        "walltime": bench_walltime.run,               # Table 7
        "serving": bench_serving.run,                 # batched engine lat/thpt
        "coldstart": bench_coldstart.run,             # boot: cold vs warmup vs cache
        "roofline": roofline.run,                     # deliverable (g)
    }
    if args.only and args.only not in suites:
        print(f"unknown suite {args.only!r}; available: {sorted(suites)}")
        sys.exit(2)
    failed = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed.append(name)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
