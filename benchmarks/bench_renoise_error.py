"""Paper Appendix C (Eq. 18): re-noise generated samples and measure
||eps - eps_theta(x_t^gen, t)||; error-robust solvers deviate less from the
model's own generation manifold."""

import jax
import jax.numpy as jnp

from benchmarks import common as C


def run() -> None:
    dlm, params, data, cfg = C.trained_model()
    eps_fn = dlm.eps_fn(params)
    xT = jax.random.normal(jax.random.PRNGKey(2), (64, 8, cfg.d_model))
    key = jax.random.PRNGKey(3)

    for solver in ("ddim", "implicit_adams_pece", "dpm_solver_fast", "era"):
        kw = {"k": 3, "error_norm": "mean"} if solver == "era" else {}
        x0 = C.solve(eps_fn, xT, solver, 10, **kw)
        errs = []
        for t in (0.2, 0.5, 0.8):
            tt = jnp.float32(t)
            eps = jax.random.normal(jax.random.fold_in(key, int(t * 100)), x0.shape)
            x_t = C.SCHEDULE.alpha(tt) * x0 + C.SCHEDULE.sigma(tt) * eps
            pred = eps_fn(x_t, tt)
            errs.append(C.rmse(pred, eps))
        C.emit(f"appC/{solver}", 0.0,
               ";".join(f"t{t}={e:.4f}" for t, e in zip((0.2, 0.5, 0.8), errs)))


if __name__ == "__main__":
    run()
