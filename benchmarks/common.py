"""Shared benchmark substrate.

FID on LSUN/Cifar10 is not computable in this container (no datasets/GPUs,
see DESIGN.md §1); every paper table is reproduced as the corresponding
*solver-quality* measurement:

  err(solver, NFE) = RMSE( x0_solver , x0_reference )

where the reference is a 400-2000 step DDIM solution of the SAME ODE (same
eps model, same x_T) — i.e. exactly the quantity FID ranks in the paper's
tables, minus the Inception embedding.  Two eps models are used:

  * ``analytic(scale)`` — closed-form optimal eps for a Gaussian-mixture
    target + controlled error injection that grows as t->0 (paper Fig. 1);
  * ``trained()``       — a small diffusion-LM trained in-repo (cached),
    whose noise estimates carry *real* learned error.
"""

from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import default_config, get_solver, linear_schedule
from repro.data import DataConfig, GaussianMixtureLatents
from repro.models import build_model
from repro.models.diffusion import DiffusionLM
from repro.training import (
    OptimizerConfig,
    checkpoint as ck,
    make_diffusion_train_step,
    train,
)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
SCHEDULE = linear_schedule()
# CI smoke mode: tiny shapes / few repeats so the whole suite runs in
# seconds on a CPU runner (Pallas kernels in interpret mode)
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


class AnalyticMixture:
    """Two-mode Gaussian mixture in R^d with exact eps* (multi-modal, so
    high-order solvers actually have curvature to exploit)."""

    def __init__(self, d=16, sep=2.0, s=0.35):
        # component means: +/- sep along the first axis
        self.c = jnp.zeros((2, d)).at[0, 0].set(sep).at[1, 0].set(-sep)
        self.s = s
        self.d = d

    def eps(self, x, t):
        a = SCHEDULE.alpha(t)
        sg = SCHEDULE.sigma(t)
        var = a * a * self.s**2 + sg * sg
        # posterior-weighted mixture score
        logw = -0.5 * jnp.sum(
            (x[..., None, :] - a * self.c) ** 2, -1
        ) / var
        w = jax.nn.softmax(logw, axis=-1)[..., None]
        mean = jnp.sum(w * (a * self.c), axis=-2)
        return (x - mean) * sg / var

    def noisy(self, scale, seed=17, late=4.0):
        def fn(x, t):
            key = jax.random.fold_in(
                jax.random.PRNGKey(seed), (t * 1e6).astype(jnp.int32)
            )
            mag = scale * (1.0 + late * jnp.exp(-6.0 * t))
            return self.eps(x, t) + mag * jax.random.normal(key, x.shape)

        return fn


@functools.lru_cache(maxsize=1)
def trained_model(steps: int = 150):
    """Train (or load) the small in-repo diffusion-LM used by benches."""
    cfg = get_config("llama3.2-1b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    path = os.path.join(ART, "bench_denoiser.npz")
    dc = DataConfig(vocab_size=1, seq_len=8, batch_size=16, kind="diffusion",
                    d_model=cfg.d_model, num_modes=2, seed=3)
    data = GaussianMixtureLatents(dc)
    if os.path.exists(path):
        tree, _ = ck.restore(path)
        params = jax.tree.map(jnp.asarray, tree["params"])
    else:
        params = dlm.init(jax.random.PRNGKey(0))
        step = make_diffusion_train_step(
            dlm, OptimizerConfig(lr=2e-3, warmup_steps=5, total_steps=steps),
            SCHEDULE,
        )
        res = train(step, params, data.batches(), steps, log_every=1000,
                    print_fn=lambda s: None)
        params = res.params
        os.makedirs(ART, exist_ok=True)
        ck.save(path, {"params": params}, steps)
    return dlm, params, data, cfg


def rmse(a, b) -> float:
    return float(jnp.sqrt(jnp.mean((a - b) ** 2)))


def reference_solution(eps_fn, xT, nfe=800):
    return get_solver("ddim")(
        eps_fn, xT, SCHEDULE, default_config("ddim", nfe=nfe)
    ).x0


def solve(eps_fn, xT, solver: str, nfe: int, **kw):
    cfg = default_config(solver, nfe=nfe, **kw) if solver == "era" else (
        default_config(solver, nfe=nfe)
    )
    return get_solver(solver)(eps_fn, xT, SCHEDULE, cfg).x0


def timer(fn, *args, repeats=3):
    fn(*args)  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, value_us: float, derived: str = "") -> None:
    """Scaffold contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{value_us:.1f},{derived}")
