"""Serving-engine benchmark: per-request latency and throughput of the
batched ERA sampling engine (`repro.serving.BatchedSampler`) at batch sizes
1 / 8 / 64, optionally swept across mesh sizes, plus a Poisson-arrival
continuous-batching sweep.

Each closed-loop scenario submits `bs` single-sample requests, drains them
as one fused batch (per-sample ERS, fused Pallas step), and reports:

  * lat_ms  — mean submit->result latency per request
  * thpt    — samples per second over the drain wall time

The first drain per bucket compiles; a warmup drain is excluded from the
timed runs, so numbers reflect the steady compiled path.

Poisson sweep (`--poisson`): an open-loop client issues single-sample
requests with exponential inter-arrival gaps at several load factors (rate =
load / single-request service time) against two servers at the same NFE:

  * baseline — per-request drains in arrival order (batch-of-1, what a
    steady stream degenerates to without continuous batching);
  * async    — the continuous-batching `AsyncBatchedSampler`, which fuses
    requests across arrival time under a `SchedulerPolicy`.

Each mode reports p50/p99 arrival-to-result latency and throughput over the
stream makespan, and the whole sweep is written as a JSON artifact
(`BENCH_serving.json` by default — the CI bench-smoke job uploads it).

Mesh sweep (`--mesh`): reruns the scenarios on 1 vs 8 virtual host devices
(`XLA_FLAGS=--xla_force_host_platform_device_count=8`, one child process per
device count since the flag binds at jax init) with the engine batch-sharded
over a ("data",) mesh — the same placement a TPU pod slice would use.

Solver sweep (`--solver-sweep`): runs **every registry solver** through one
engine via per-request `solver=` routing (the PR-4 solver-program refactor:
each baseline gets the same single-scan compile, donated buffers, and
bucketed batching ERA has) at batch sizes 1 and 8, and writes
`BENCH_solvers.json` — steady-state walltime/throughput and the number of
XLA programs compiled per solver (the CI bench-smoke job uploads it).
This is the engine-side substrate for the paper's comparison tables: every
solver rides the same serving path, so walltime differences are solver
math, not engine favoritism.

Seq-mix sweep (`--seq-mix`): an open-loop Poisson client draws each
request's `seq_len` from a mixed distribution and streams it at two
continuous-batching servers:

  * exact — grouping by exact `(solver, seq_len, nfe)`: realistic
    heterogeneous traffic fragments into per-length queues that rarely
    fill a bucket, and every distinct length compiles its own programs;
  * fused — seq bucketing (`seq_buckets=` ladder): mixed lengths
    right-pad into shared length-masked batches, so queues fill across
    lengths and the compile count is bounded by the ladder.

Both modes report p50/p99 latency, throughput, mean fused batch rows, and
compiled-program counts; the sweep is written as `BENCH_seqmix.json` (the
CI bench-smoke job uploads it).  See `docs/serving.md` for the masking
contract that makes fused results bit-identical to exact-shape runs.

NFE-mix sweep (`--nfe-mix`): an open-loop Poisson client draws each
request's NFE budget from a mixed distribution (all at one seq_len) and
streams it at two continuous-batching servers:

  * exact — grouping by exact `(solver, seq_len, nfe)`: every distinct
    budget fragments into its own queue and compiles its own programs;
  * fused — NFE bucketing (`nfe_buckets=` ladder): mixed budgets scan to
    the bucketed max NFE with per-row step masks, so queues fill across
    budgets and the compile count is bounded by the ladder.

Both modes report p50/p99 latency, throughput, compiled-program counts,
and the wasted padding step-rows counter; the sweep is written as
`BENCH_nfemix.json` (the CI bench-smoke job uploads it).  Unlike the
seq-mix warnings, the ladder bound is enforced: the sweep exits non-zero
if fused traffic compiles more programs than |nfe_buckets| x
|batch_buckets| or compiles any off-ladder NFE.

Front-door sweep (`--frontdoor`): boots the real HTTP server as a
subprocess (`python -m repro.launch.serve --listen --port 0`, waiting on
its `FRONTDOOR READY <url>` line), then drives an open-loop Poisson client
over the wire — every request pays JSON + base64 + loopback TCP, and
concurrent wire requests fuse in the server's scheduler exactly like
in-process submits.  Reports wire p50/p99 arrival-to-result latency and
throughput per load, scrapes `/metrics` and asserts the serving
instruments are present, and writes `BENCH_frontdoor.json` (the CI
bench-smoke job uploads it).
"""

import argparse
import json
import os
import queue
import subprocess
import sys
import threading
import time

import numpy as np

from benchmarks import common as C
from repro.core import solver_names
from repro.serving import (
    AsyncBatchedSampler,
    BatchedSampler,
    FrontDoorClient,
    SampleRequest,
    SchedulerPolicy,
    open_loop,
    result_keys as K,
)

MESH_SWEEP_DEVICES = (1, 8)
POISSON_LOADS = (4.0, 8.0)  # arrival rate as a multiple of 1/t_single
POISSON_REPEATS = 2         # streams per mode; best-throughput run reported


def run(mesh=None) -> None:
    dlm, params, data, cfg = C.trained_model(30 if C.SMOKE else 150)
    nfe = 6 if C.SMOKE else 10
    seq = 8
    batch_sizes = (1, 8) if C.SMOKE else (1, 8, 64)
    engine = BatchedSampler(
        dlm, C.SCHEDULE, batch_buckets=tuple(batch_sizes), mesh=mesh
    )
    tag = f"serving/era/dp{engine.dp}" if mesh is not None else "serving/era"

    for bs in batch_sizes:
        def drain_once(offset: int):
            tickets = [
                engine.submit_with_future(
                    SampleRequest(batch=1, seq_len=seq, nfe=nfe, seed=offset + i)
                )[0]
                for i in range(bs)
            ]
            t0 = time.perf_counter()
            results = engine.drain(params)
            wall = time.perf_counter() - t0
            return tickets, results, wall

        drain_once(0)  # compile warmup for this bucket
        repeats = 1 if C.SMOKE else 3
        best_wall, lat = float("inf"), 0.0
        for r in range(repeats):
            tickets, results, wall = drain_once(1000 * (r + 1))
            if wall < best_wall:
                best_wall = wall
                lat = sum(results[t].latency_s for t in tickets) / bs
        thpt = bs / best_wall
        C.emit(
            f"{tag}/bs{bs}",
            best_wall * 1e6,
            f"lat_ms={lat * 1e3:.2f},thpt={thpt:.1f}/s",
        )

    # compile-cache sanity: one program per bucket regardless of traffic
    C.emit(
        f"{tag}/compiled_buckets",
        float(len(engine.compile_cache())),
        f"buckets={sorted(k[2] for k in engine.compile_cache())}",
    )


def _percentiles(lats_s) -> dict:
    arr = np.asarray(lats_s) * 1e3
    return {
        "p50_ms": float(np.percentile(arr, 50)),
        "p99_ms": float(np.percentile(arr, 99)),
    }


def _poisson_gaps(rng, n: int, rate: float):
    return rng.exponential(1.0 / rate, n)


def _request(seq: int, nfe: int, seed: int) -> SampleRequest:
    return SampleRequest(batch=1, seq_len=seq, nfe=nfe, seed=seed)


def _run_baseline(engine, params, gaps, seq, nfe):
    """Per-request drain server: arrivals queue FIFO, one batch-of-1 drain
    each — the shape a steady stream degenerates to without continuous
    batching.  Returns (per-request latencies, makespan)."""
    work: queue.Queue = queue.Queue()
    lats = []

    def server():
        while True:
            item = work.get()
            if item is None:
                return
            t_arrive, req = item
            engine.submit_with_future(req)
            engine.drain(params)
            lats.append(time.perf_counter() - t_arrive)

    th = threading.Thread(target=server)
    th.start()
    t_start = open_loop(
        gaps,
        lambda i: work.put((time.perf_counter(), _request(seq, nfe, 2000 + i))),
    )
    work.put(None)
    th.join()
    return lats, time.perf_counter() - t_start


def _run_async(engine, params, gaps, seq, nfe, policy):
    """Open-loop client against the continuous-batching scheduler."""
    futures = []
    with AsyncBatchedSampler(engine, params, policy) as sched:
        t_start = open_loop(
            gaps,
            lambda i: futures.append(sched.submit(_request(seq, nfe, 2000 + i))),
        )
        results = [f.result() for f in futures]
        makespan = time.perf_counter() - t_start
        stats = sched.stats()
    return [r.latency_s for r in results], makespan, stats


def run_poisson(out_path: str = "BENCH_serving.json") -> None:
    """Continuous batching vs per-request drains under Poisson arrivals."""
    dlm, params, data, cfg = C.trained_model(30 if C.SMOKE else 150)
    nfe = 6 if C.SMOKE else 10
    seq = 8
    n_req = 32 if C.SMOKE else 96
    # finer buckets than the closed-loop bench: continuous batching launches
    # whatever accumulated, so a half-full largest bucket must not pay
    # full-bucket padding cost
    buckets = (1, 2, 4, 8) if C.SMOKE else (1, 2, 4, 8, 16, 64)
    engine = BatchedSampler(dlm, C.SCHEDULE, batch_buckets=buckets)

    # compile every bucket program before any timed stream
    for bucket in buckets:
        for i in range(bucket):
            engine.submit_with_future(_request(seq, nfe, 9000 + i))
        engine.drain(params)

    # single-request service time anchors the arrival rates
    t_single = float("inf")
    for r in range(3):
        engine.submit_with_future(_request(seq, nfe, 9100 + r))
        t0 = time.perf_counter()
        engine.drain(params)
        t_single = min(t_single, time.perf_counter() - t0)

    policy = SchedulerPolicy(
        max_wait_ms=max(1.0, 2 * t_single * 1e3), target_occupancy=1.0
    )
    record = {
        "bench": "serving/poisson",
        "smoke": C.SMOKE,
        "nfe": nfe,
        "seq_len": seq,
        "requests": n_req,
        "buckets": list(buckets),
        "t_single_s": t_single,
        "policy": {
            "max_wait_ms": policy.max_wait_ms,
            "target_occupancy": policy.target_occupancy,
        },
        "sweep": [],
    }
    rng = np.random.default_rng(0)
    for load in POISSON_LOADS:
        rate = load / t_single
        gaps = _poisson_gaps(rng, n_req, rate)
        # repeat each stream and keep the best-throughput run: an open-loop
        # stream is one realization, and a CPU-contended repeat would
        # otherwise masquerade as a scheduling result
        base = asyn = None
        for _ in range(POISSON_REPEATS):
            lats, span = _run_baseline(engine, params, gaps, seq, nfe)
            cand = {"throughput_rps": n_req / span, **_percentiles(lats)}
            if base is None or cand["throughput_rps"] > base["throughput_rps"]:
                base = cand
        for _ in range(POISSON_REPEATS):
            lats, span, stats = _run_async(
                engine, params, gaps, seq, nfe, policy
            )
            cand = {
                "throughput_rps": n_req / span,
                K.MEAN_BATCH_ROWS: stats[K.MEAN_BATCH_ROWS],
                K.BATCHES: stats[K.BATCHES],
                **_percentiles(lats),
            }
            if asyn is None or cand["throughput_rps"] > asyn["throughput_rps"]:
                asyn = cand
        entry = {
            "load": load,
            "rate_rps": rate,
            "baseline": base,
            "async": asyn,
            "speedup": asyn["throughput_rps"] / base["throughput_rps"],
        }
        record["sweep"].append(entry)
        for mode, rec in (("baseline", base), ("async", asyn)):
            C.emit(
                f"serving/era/poisson/load{load:g}/{mode}",
                rec["p50_ms"] * 1e3,
                f"p99_ms={rec['p99_ms']:.2f},thpt={rec['throughput_rps']:.1f}/s",
            )
        C.emit(
            f"serving/era/poisson/load{load:g}/speedup",
            entry["speedup"] * 1e6,
            f"async_thpt/base_thpt={entry['speedup']:.2f}x,"
            f"mean_batch_rows={asyn[K.MEAN_BATCH_ROWS]:.1f}",
        )

    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_path}")
    worst = min(e["speedup"] for e in record["sweep"])
    if worst <= 1.0:
        print(
            f"# WARNING: async throughput did not beat the per-request "
            f"baseline at some load (min speedup {worst:.2f}x)"
        )


def run_solver_sweep(out_path: str = "BENCH_solvers.json") -> None:
    """Every registry solver through the engine at bs 1 / 8 via per-request
    routing: steady-state walltime + compile count per solver."""
    dlm, params, data, cfg = C.trained_model(30 if C.SMOKE else 150)
    nfe = 6 if C.SMOKE else 10
    seq = 8
    batch_sizes = (1, 8)
    engine = BatchedSampler(dlm, C.SCHEDULE, batch_buckets=batch_sizes)
    record = {
        "bench": "serving/solver-sweep",
        "smoke": C.SMOKE,
        "nfe": nfe,
        "seq_len": seq,
        "batch_sizes": list(batch_sizes),
        "solvers": {},
    }

    for solver in solver_names():
        compiled_before = len(engine.compile_cache())
        entry = {"buckets": {}}
        for bs in batch_sizes:

            def drain_once(offset: int):
                tickets = [
                    engine.submit_with_future(
                        SampleRequest(
                            batch=1,
                            seq_len=seq,
                            nfe=nfe,
                            solver=solver,
                            seed=offset + i,
                        )
                    )[0]
                    for i in range(bs)
                ]
                t0 = time.perf_counter()
                results = engine.drain(params)
                wall = time.perf_counter() - t0
                return tickets, results, wall

            drain_once(0)  # compile warmup for this (solver, bucket)
            repeats = 1 if C.SMOKE else 3
            best_wall, lat = float("inf"), 0.0
            for r in range(repeats):
                tickets, results, wall = drain_once(1000 * (r + 1))
                if wall < best_wall:
                    best_wall = wall
                    lat = sum(results[t].latency_s for t in tickets) / bs
            entry["buckets"][str(bs)] = {
                K.WALL_S: best_wall,
                "lat_ms": lat * 1e3,
                "throughput_rps": bs / best_wall,
            }
            C.emit(
                f"serving/sweep/{solver}/bs{bs}",
                best_wall * 1e6,
                f"lat_ms={lat * 1e3:.2f},thpt={bs / best_wall:.1f}/s",
            )
        # compile accounting: each solver should add exactly one XLA program
        # per batch bucket it ran at, and no solver recompiles another's
        entry["compiled_programs"] = len(engine.compile_cache()) - compiled_before
        record["solvers"][solver] = entry

    record["total_compiled_programs"] = len(engine.compile_cache())
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_path}")
    expected = len(batch_sizes)
    for solver, entry in record["solvers"].items():
        if entry["compiled_programs"] > expected:
            print(
                f"# WARNING: {solver} compiled {entry['compiled_programs']} "
                f"programs (expected <= {expected} — one per bucket)"
            )


def run_seq_mix(out_path: str = "BENCH_seqmix.json") -> None:
    """Mixed-seq-len open-loop sweep: seq bucketing + padding masks vs
    exact-shape grouping, same traffic, same policy, same NFE."""
    dlm, params, data, cfg = C.trained_model(30 if C.SMOKE else 150)
    nfe = 6 if C.SMOKE else 10
    n_req = 24 if C.SMOKE else 96
    batch_buckets = (1, 2, 4, 8)
    if C.SMOKE:
        seq_lens = (2, 3, 4, 6, 8)
        seq_buckets = (4, 8)
    else:
        seq_lens = (4, 6, 8, 12, 16, 20, 28, 32)
        seq_buckets = (8, 16, 32)
    rng = np.random.default_rng(0)
    lengths = [int(x) for x in rng.choice(seq_lens, n_req)]

    # service-time anchor: a single largest-length request, exact shape
    anchor = BatchedSampler(dlm, C.SCHEDULE, batch_buckets=batch_buckets)
    t_single = float("inf")
    for r in range(3):
        anchor.submit_with_future(_request(max(seq_lens), nfe, 9500 + r))
        t0 = time.perf_counter()
        anchor.drain(params)
        t_single = min(t_single, time.perf_counter() - t0)

    load = 4.0
    gaps = _poisson_gaps(rng, n_req, load / t_single)
    policy = SchedulerPolicy(
        max_wait_ms=max(1.0, 2 * t_single * 1e3), target_occupancy=1.0
    )
    record = {
        "bench": "serving/seq-mix",
        "smoke": C.SMOKE,
        "nfe": nfe,
        "requests": n_req,
        "load": load,
        "t_single_s": t_single,
        "seq_len_distribution": list(seq_lens),
        "seq_buckets": list(seq_buckets),
        "batch_buckets": list(batch_buckets),
        "policy": {
            "max_wait_ms": policy.max_wait_ms,
            "target_occupancy": policy.target_occupancy,
        },
        "modes": {},
    }

    def stream(engine):
        futures = []
        with AsyncBatchedSampler(engine, params, policy) as sched:
            t_start = open_loop(
                gaps,
                lambda i: futures.append(
                    sched.submit(_request(lengths[i], nfe, 3000 + i))
                ),
            )
            results = [f.result() for f in futures]
            makespan = time.perf_counter() - t_start
            stats = sched.stats()
        return [r.latency_s for r in results], makespan, stats

    for mode, ladder in (("exact", None), ("fused", seq_buckets)):
        engine = BatchedSampler(
            dlm, C.SCHEDULE, batch_buckets=batch_buckets, seq_buckets=ladder
        )
        stream(engine)  # untimed warm stream: compiles the hot buckets
        best = None
        for _ in range(POISSON_REPEATS):
            lats, span, stats = stream(engine)
            cand = {
                "throughput_rps": n_req / span,
                K.MEAN_BATCH_ROWS: stats[K.MEAN_BATCH_ROWS],
                K.BATCHES: stats[K.BATCHES],
                **_percentiles(lats),
            }
            if best is None or cand["throughput_rps"] > best["throughput_rps"]:
                best = cand
        best["compiled_programs"] = len(engine.compile_cache())
        best["compiled_seq_lens"] = sorted({k[3] for k in engine.compile_cache()})
        record["modes"][mode] = best
        C.emit(
            f"serving/seqmix/{mode}",
            best["p50_ms"] * 1e3,
            f"p99_ms={best['p99_ms']:.2f},thpt={best['throughput_rps']:.1f}/s,"
            f"compiles={best['compiled_programs']},"
            f"rows/batch={best[K.MEAN_BATCH_ROWS]:.1f}",
        )

    fused, exact = record["modes"]["fused"], record["modes"]["exact"]
    record["speedup"] = fused["throughput_rps"] / exact["throughput_rps"]
    C.emit(
        "serving/seqmix/speedup",
        record["speedup"] * 1e6,
        f"fused_thpt/exact_thpt={record['speedup']:.2f}x,"
        f"compiles_fused={fused['compiled_programs']},"
        f"compiles_exact={exact['compiled_programs']}",
    )
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_path}")
    # the two structural claims of seq bucketing, checked on every run
    max_fused = len(seq_buckets) * len(batch_buckets)
    if fused["compiled_programs"] > max_fused:
        print(
            f"# WARNING: fused mode compiled {fused['compiled_programs']} "
            f"programs (> ladder x batch buckets = {max_fused})"
        )
    if not set(fused["compiled_seq_lens"]) <= set(seq_buckets):
        print(
            f"# WARNING: fused mode compiled off-ladder seq lens "
            f"{fused['compiled_seq_lens']}"
        )
    if record["speedup"] <= 1.0:
        print(
            f"# WARNING: fused mixed-length throughput did not beat the "
            f"exact-shape baseline (speedup {record['speedup']:.2f}x)"
        )


def run_nfe_mix(out_path: str = "BENCH_nfemix.json") -> None:
    """Mixed-NFE open-loop sweep: NFE bucketing + per-row step masks vs
    exact-NFE grouping, same traffic, same policy, same seq_len.

    Exits non-zero if the fused mode compiles more programs than the
    ladder bounds (|nfe_buckets| x |batch_buckets|) or compiles any
    off-ladder NFE — the structural claim NFE bucketing makes to CI.
    """
    dlm, params, data, cfg = C.trained_model(30 if C.SMOKE else 150)
    seq = 4 if C.SMOKE else 16
    n_req = 24 if C.SMOKE else 96
    batch_buckets = (1, 2, 4, 8)
    if C.SMOKE:
        nfes = (4, 5, 6)  # ERA floor: nfe >= k (engine default k=4)
        nfe_buckets = (4, 6)
    else:
        nfes = (10, 14, 18, 22, 25)
        nfe_buckets = (18, 32)
    rng = np.random.default_rng(0)
    budgets = [int(x) for x in rng.choice(nfes, n_req)]

    # service-time anchor: a single largest-budget request, exact shape
    anchor = BatchedSampler(dlm, C.SCHEDULE, batch_buckets=batch_buckets)
    t_single = float("inf")
    for r in range(3):
        anchor.submit_with_future(_request(seq, max(nfes), 9600 + r))
        t0 = time.perf_counter()
        anchor.drain(params)
        t_single = min(t_single, time.perf_counter() - t0)

    load = 4.0
    gaps = _poisson_gaps(rng, n_req, load / t_single)
    policy = SchedulerPolicy(
        max_wait_ms=max(1.0, 2 * t_single * 1e3), target_occupancy=1.0
    )
    record = {
        "bench": "serving/nfe-mix",
        "smoke": C.SMOKE,
        "seq_len": seq,
        "requests": n_req,
        "load": load,
        "t_single_s": t_single,
        "nfe_distribution": list(nfes),
        "nfe_buckets": list(nfe_buckets),
        "batch_buckets": list(batch_buckets),
        "policy": {
            "max_wait_ms": policy.max_wait_ms,
            "target_occupancy": policy.target_occupancy,
        },
        "modes": {},
    }

    def stream(engine):
        futures = []
        with AsyncBatchedSampler(engine, params, policy) as sched:
            t_start = open_loop(
                gaps,
                lambda i: futures.append(
                    sched.submit(_request(seq, budgets[i], 3500 + i))
                ),
            )
            results = [f.result() for f in futures]
            makespan = time.perf_counter() - t_start
            stats = sched.stats()
        return [r.latency_s for r in results], makespan, stats

    for mode, ladder in (("exact", None), ("fused", nfe_buckets)):
        engine = BatchedSampler(
            dlm, C.SCHEDULE, batch_buckets=batch_buckets, nfe_buckets=ladder
        )
        stream(engine)  # untimed warm stream: compiles the hot buckets
        best = None
        for _ in range(POISSON_REPEATS):
            lats, span, stats = stream(engine)
            cand = {
                "throughput_rps": n_req / span,
                K.MEAN_BATCH_ROWS: stats[K.MEAN_BATCH_ROWS],
                K.BATCHES: stats[K.BATCHES],
                **_percentiles(lats),
            }
            if best is None or cand["throughput_rps"] > best["throughput_rps"]:
                best = cand
        best["compiled_programs"] = len(engine.compile_cache())
        # the fuse key carries the scanned-to NFE in its config slot
        best["compiled_nfes"] = sorted(
            {k[1].nfe for k in engine.compile_cache()}
        )
        pad_rows = engine.executor.metrics.get("sampler_nfe_padding_rows_total")
        best["nfe_padding_rows"] = (
            pad_rows.value(solver=engine.executor.solver_name)
            if pad_rows
            else 0.0
        )
        record["modes"][mode] = best
        C.emit(
            f"serving/nfemix/{mode}",
            best["p50_ms"] * 1e3,
            f"p99_ms={best['p99_ms']:.2f},thpt={best['throughput_rps']:.1f}/s,"
            f"compiles={best['compiled_programs']},"
            f"rows/batch={best[K.MEAN_BATCH_ROWS]:.1f}",
        )

    fused, exact = record["modes"]["fused"], record["modes"]["exact"]
    record["speedup"] = fused["throughput_rps"] / exact["throughput_rps"]
    C.emit(
        "serving/nfemix/speedup",
        record["speedup"] * 1e6,
        f"fused_thpt/exact_thpt={record['speedup']:.2f}x,"
        f"compiles_fused={fused['compiled_programs']},"
        f"compiles_exact={exact['compiled_programs']}",
    )
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_path}")
    # the structural claims of NFE bucketing, enforced (not just warned):
    # mixed-NFE traffic must never compile past the ladder
    failures = []
    max_fused = len(nfe_buckets) * len(batch_buckets)
    if fused["compiled_programs"] > max_fused:
        failures.append(
            f"fused mode compiled {fused['compiled_programs']} programs "
            f"(> nfe ladder x batch buckets = {max_fused})"
        )
    if not set(fused["compiled_nfes"]) <= set(nfe_buckets):
        failures.append(
            f"fused mode compiled off-ladder NFEs {fused['compiled_nfes']}"
        )
    if record["speedup"] <= 1.0:
        print(
            f"# WARNING: fused mixed-NFE throughput did not beat the "
            f"exact-NFE baseline (speedup {record['speedup']:.2f}x)"
        )
    for msg in failures:
        print(f"# FAIL: {msg}")
    if failures:
        raise SystemExit(1)


FRONTDOOR_LOADS = (2.0, 4.0)
# instruments the /metrics scrape must expose (acceptance contract —
# see docs/serving.md)
FRONTDOOR_REQUIRED_METRICS = (
    "sampler_queue_depth_rows",
    "sampler_fuse_occupancy_ratio",
    "sampler_compile_cache_hits_total",
    "sampler_compile_cache_misses_total",
    "sampler_compile_programs_total",
    "sampler_compile_seconds",
    "sampler_warmup_grid_programs",
    "sampler_warmup_compiled_programs",
    "sampler_warmup_in_progress",
    "sampler_warmup_duration_seconds",
    "sampler_warmup_programs_total",
    "sampler_admission_rejects_total",
    "sampler_masked_fallback_total",
    "sampler_nfe_padding_rows_total",
    "sampler_request_latency_seconds",
    "frontdoor_http_requests_total",
)


def _boot_frontdoor_server(nfe: int, seq: int, max_wait_ms: float):
    """Launch `repro.launch.serve --listen --port 0` as a subprocess and
    wait for its `FRONTDOOR READY <url>` sentinel.  Returns (proc, url)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.launch.serve",
            "--arch", "llama3.2-1b", "--smoke", "--mode", "diffusion",
            "--listen", "--port", "0", "--nfe", str(nfe), "--seq", str(seq),
            "--max-wait-ms", str(max_wait_ms),
            # finer ladder than the serving default: an open-loop stream
            # launches whatever accumulated (same reasoning as --poisson),
            # and the warmup only has these buckets to compile
            "--batch-buckets", "1,2,4,8",
        ],
        stdout=subprocess.PIPE,
        text=True,
        cwd=root,
        env=env,
    )
    try:
        for line in proc.stdout:
            if line.startswith("FRONTDOOR READY "):
                return proc, line.split()[-1].strip()
        raise RuntimeError(
            f"server exited (rc={proc.wait()}) before the ready line"
        )
    except Exception:
        proc.terminate()
        raise


def run_frontdoor(out_path: str = "BENCH_frontdoor.json") -> None:
    """Open-loop Poisson sweep over the wire: the real HTTP server in a
    subprocess, one client thread per in-flight request, every sample
    paying JSON + base64 + loopback TCP on top of the engine."""
    nfe = 6 if C.SMOKE else 10
    seq = 8
    n_req = 24 if C.SMOKE else 96
    proc, url = _boot_frontdoor_server(nfe, seq, max_wait_ms=25.0)
    try:
        client = FrontDoorClient(url, timeout=600.0)

        # the ready line means *bound*, not *warm* — the AOT warmup grid
        # compiles on a background thread behind /readyz.  Wait it out so
        # t_single anchors on solver time, not the compile wall.
        t_deadline = time.perf_counter() + 600.0
        while not client.readyz()["ready"]:
            if time.perf_counter() > t_deadline:
                raise RuntimeError(f"server never ready: {client.readyz()}")
            time.sleep(0.25)

        # single-request wire service time anchors the arrival rates
        t_single = float("inf")
        for i in range(3):
            t0 = time.perf_counter()
            client.sample(_request(seq, nfe, 9200 + i))
            t_single = min(t_single, time.perf_counter() - t0)

        def stream(gaps, seed0: int):
            lats = [None] * len(gaps)
            threads = []

            def fire(i: int):
                def call():
                    t0 = time.perf_counter()
                    client.sample(_request(seq, nfe, seed0 + i))
                    lats[i] = time.perf_counter() - t0

                th = threading.Thread(target=call)
                th.start()
                threads.append(th)

            t_start = open_loop(gaps, fire)
            for th in threads:
                th.join()
            return lats, time.perf_counter() - t_start

        record = {
            "bench": "serving/frontdoor",
            "smoke": C.SMOKE,
            "nfe": nfe,
            "seq_len": seq,
            "requests": n_req,
            "t_single_wire_s": t_single,
            "url": url,
            "sweep": [],
        }
        rng = np.random.default_rng(0)
        for load in FRONTDOOR_LOADS:
            rate = load / t_single
            best = None
            for r in range(POISSON_REPEATS):
                lats, span = stream(
                    _poisson_gaps(rng, n_req, rate), 4000 + 1000 * r
                )
                cand = {"throughput_rps": n_req / span, **_percentiles(lats)}
                if best is None or cand["throughput_rps"] > best["throughput_rps"]:
                    best = cand
            record["sweep"].append({"load": load, "rate_rps": rate, **best})
            C.emit(
                f"serving/era/frontdoor/load{load:g}",
                best["p50_ms"] * 1e3,
                f"p99_ms={best['p99_ms']:.2f},thpt={best['throughput_rps']:.1f}/s",
            )

        # /metrics scrape: the serving instruments must all be present
        scrape = client.metrics()
        missing = [m for m in FRONTDOOR_REQUIRED_METRICS if m not in scrape]
        if missing:
            raise RuntimeError(f"/metrics is missing instruments: {missing}")
        record["metrics_ok"] = True
        record["healthz"] = client.healthz()["stats"]
        record["readyz_warmup"] = client.readyz()["warmup"]
    finally:
        proc.terminate()
        proc.wait()

    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_path}")


def run_on_local_mesh() -> None:
    """Child entry for the mesh sweep: engine sharded over all local devices
    (a 1-device mesh degenerates to the plain path, same program)."""
    import jax

    from repro.launch.mesh import make_sampler_mesh

    print(f"# mesh child: {jax.device_count()} device(s)", flush=True)
    run(mesh=make_sampler_mesh())


def run_mesh_sweep() -> None:
    """1 vs N virtual devices, one subprocess per device count (XLA_FLAGS
    must be set before jax initializes)."""
    for n in MESH_SWEEP_DEVICES:
        env = dict(os.environ)
        flags = f"--xla_force_host_platform_device_count={n}"
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flags).strip()
        # the flag only multiplies CPU devices; pin the child to CPU so the
        # sweep doesn't silently bench a 1-GPU mesh twice
        env["JAX_PLATFORMS"] = "cpu"
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_serving", "--mesh-child"],
            env=env,
        )
        if proc.returncode != 0:
            raise RuntimeError(f"mesh sweep child (devices={n}) failed")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--mesh",
        action="store_true",
        help="sweep the engine over 1 vs 8 virtual host devices",
    )
    ap.add_argument(
        "--mesh-child",
        action="store_true",
        help="(internal) run sharded over whatever devices this process has",
    )
    ap.add_argument(
        "--poisson",
        action="store_true",
        help="open-loop Poisson-arrival sweep: continuous batching vs "
        "per-request drains",
    )
    ap.add_argument(
        "--solver-sweep",
        action="store_true",
        help="run every registry solver through the engine at bs 1/8 via "
        "per-request routing; writes walltime + compile count per solver",
    )
    ap.add_argument(
        "--seq-mix",
        action="store_true",
        help="open-loop mixed-seq-len sweep: seq bucketing + padding masks "
        "vs exact-shape grouping; writes BENCH_seqmix.json",
    )
    ap.add_argument(
        "--nfe-mix",
        action="store_true",
        help="open-loop mixed-NFE sweep: NFE bucketing + per-row step masks "
        "vs exact-NFE grouping; writes BENCH_nfemix.json and fails if "
        "fused traffic compiles more programs than the ladder bounds",
    )
    ap.add_argument(
        "--frontdoor",
        action="store_true",
        help="open-loop Poisson sweep over the wire against a subprocess "
        "HTTP front-door server; writes BENCH_frontdoor.json",
    )
    ap.add_argument(
        "--out",
        default=None,
        help="JSON artifact path (default BENCH_serving.json for --poisson, "
        "BENCH_solvers.json for --solver-sweep, BENCH_seqmix.json for "
        "--seq-mix, BENCH_nfemix.json for --nfe-mix, BENCH_frontdoor.json "
        "for --frontdoor)",
    )
    args = ap.parse_args()
    if args.mesh:
        run_mesh_sweep()
    elif args.mesh_child:
        run_on_local_mesh()
    elif args.poisson:
        run_poisson(args.out or "BENCH_serving.json")
    elif args.solver_sweep:
        run_solver_sweep(args.out or "BENCH_solvers.json")
    elif args.seq_mix:
        run_seq_mix(args.out or "BENCH_seqmix.json")
    elif args.nfe_mix:
        run_nfe_mix(args.out or "BENCH_nfemix.json")
    elif args.frontdoor:
        run_frontdoor(args.out or "BENCH_frontdoor.json")
    else:
        run()
