"""Serving-engine benchmark: per-request latency and throughput of the
batched ERA sampling engine (`repro.serving.BatchedSampler`) at batch sizes
1 / 8 / 64.

Each scenario submits `bs` single-sample requests, drains them as one fused
batch (per-sample ERS, fused Pallas step), and reports:

  * lat_ms  — mean submit->result latency per request
  * thpt    — samples per second over the drain wall time

The first drain per bucket compiles; a warmup drain is excluded from the
timed runs, so numbers reflect the steady compiled path.
"""

import time

from benchmarks import common as C
from repro.serving import BatchedSampler, SampleRequest


def run() -> None:
    dlm, params, data, cfg = C.trained_model(30 if C.SMOKE else 150)
    nfe = 6 if C.SMOKE else 10
    seq = 8
    batch_sizes = (1, 8) if C.SMOKE else (1, 8, 64)
    engine = BatchedSampler(
        dlm, C.SCHEDULE, batch_buckets=tuple(batch_sizes)
    )

    for bs in batch_sizes:
        def drain_once(offset: int):
            tickets = [
                engine.submit(
                    SampleRequest(batch=1, seq_len=seq, nfe=nfe, seed=offset + i)
                )
                for i in range(bs)
            ]
            t0 = time.perf_counter()
            results = engine.drain(params)
            wall = time.perf_counter() - t0
            return tickets, results, wall

        drain_once(0)  # compile warmup for this bucket
        repeats = 1 if C.SMOKE else 3
        best_wall, lat = float("inf"), 0.0
        for r in range(repeats):
            tickets, results, wall = drain_once(1000 * (r + 1))
            if wall < best_wall:
                best_wall = wall
                lat = sum(results[t].latency_s for t in tickets) / bs
        thpt = bs / best_wall
        C.emit(
            f"serving/era/bs{bs}",
            best_wall * 1e6,
            f"lat_ms={lat * 1e3:.2f},thpt={thpt:.1f}/s",
        )

    # compile-cache sanity: one program per bucket regardless of traffic
    C.emit(
        "serving/era/compiled_buckets",
        float(len(engine.compile_cache())),
        f"buckets={sorted(k[2] for k in engine.compile_cache())}",
    )


if __name__ == "__main__":
    run()
