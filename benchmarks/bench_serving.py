"""Serving-engine benchmark: per-request latency and throughput of the
batched ERA sampling engine (`repro.serving.BatchedSampler`) at batch sizes
1 / 8 / 64, optionally swept across mesh sizes.

Each scenario submits `bs` single-sample requests, drains them as one fused
batch (per-sample ERS, fused Pallas step), and reports:

  * lat_ms  — mean submit->result latency per request
  * thpt    — samples per second over the drain wall time

The first drain per bucket compiles; a warmup drain is excluded from the
timed runs, so numbers reflect the steady compiled path.

Mesh sweep (`--mesh`): reruns the scenarios on 1 vs 8 virtual host devices
(`XLA_FLAGS=--xla_force_host_platform_device_count=8`, one child process per
device count since the flag binds at jax init) with the engine batch-sharded
over a ("data",) mesh — the same placement a TPU pod slice would use.
"""

import argparse
import os
import subprocess
import sys
import time

from benchmarks import common as C
from repro.serving import BatchedSampler, SampleRequest

MESH_SWEEP_DEVICES = (1, 8)


def run(mesh=None) -> None:
    dlm, params, data, cfg = C.trained_model(30 if C.SMOKE else 150)
    nfe = 6 if C.SMOKE else 10
    seq = 8
    batch_sizes = (1, 8) if C.SMOKE else (1, 8, 64)
    engine = BatchedSampler(
        dlm, C.SCHEDULE, batch_buckets=tuple(batch_sizes), mesh=mesh
    )
    tag = f"serving/era/dp{engine.dp}" if mesh is not None else "serving/era"

    for bs in batch_sizes:
        def drain_once(offset: int):
            tickets = [
                engine.submit(
                    SampleRequest(batch=1, seq_len=seq, nfe=nfe, seed=offset + i)
                )
                for i in range(bs)
            ]
            t0 = time.perf_counter()
            results = engine.drain(params)
            wall = time.perf_counter() - t0
            return tickets, results, wall

        drain_once(0)  # compile warmup for this bucket
        repeats = 1 if C.SMOKE else 3
        best_wall, lat = float("inf"), 0.0
        for r in range(repeats):
            tickets, results, wall = drain_once(1000 * (r + 1))
            if wall < best_wall:
                best_wall = wall
                lat = sum(results[t].latency_s for t in tickets) / bs
        thpt = bs / best_wall
        C.emit(
            f"{tag}/bs{bs}",
            best_wall * 1e6,
            f"lat_ms={lat * 1e3:.2f},thpt={thpt:.1f}/s",
        )

    # compile-cache sanity: one program per bucket regardless of traffic
    C.emit(
        f"{tag}/compiled_buckets",
        float(len(engine.compile_cache())),
        f"buckets={sorted(k[2] for k in engine.compile_cache())}",
    )


def run_on_local_mesh() -> None:
    """Child entry for the mesh sweep: engine sharded over all local devices
    (a 1-device mesh degenerates to the plain path, same program)."""
    import jax

    from repro.launch.mesh import make_sampler_mesh

    print(f"# mesh child: {jax.device_count()} device(s)", flush=True)
    run(mesh=make_sampler_mesh())


def run_mesh_sweep() -> None:
    """1 vs N virtual devices, one subprocess per device count (XLA_FLAGS
    must be set before jax initializes)."""
    for n in MESH_SWEEP_DEVICES:
        env = dict(os.environ)
        flags = f"--xla_force_host_platform_device_count={n}"
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flags).strip()
        # the flag only multiplies CPU devices; pin the child to CPU so the
        # sweep doesn't silently bench a 1-GPU mesh twice
        env["JAX_PLATFORMS"] = "cpu"
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_serving", "--mesh-child"],
            env=env,
        )
        if proc.returncode != 0:
            raise RuntimeError(f"mesh sweep child (devices={n}) failed")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--mesh",
        action="store_true",
        help="sweep the engine over 1 vs 8 virtual host devices",
    )
    ap.add_argument(
        "--mesh-child",
        action="store_true",
        help="(internal) run sharded over whatever devices this process has",
    )
    args = ap.parse_args()
    if args.mesh:
        run_mesh_sweep()
    elif args.mesh_child:
        run_on_local_mesh()
    else:
        run()
