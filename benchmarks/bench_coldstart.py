"""Cold-start benchmark: what does a replica boot cost, and what do AOT
warmup + the persistent compilation cache buy back?

Four boot scenarios over the same engine shape (trained smoke denoiser,
batch-bucket x seq-bucket x nfe grid), measured from engine construction:

* ``cold``        — no warmup: the first request of every shape pays its
  own XLA compile at drain time (the pre-warmup serving behavior).
* ``aot``         — ``BatchedSampler.warmup()``: the grid is lowered and
  compiled from abstract shapes before the first request (no sampling).
* ``cache_cold``  — AOT warmup with a *fresh* persistent compilation
  cache dir: same compile wall as ``aot``, but every program is written
  to disk (the first deploy of a fleet).
* ``cache_warm``  — AOT warmup against the now-populated cache dir: the
  redeploy path, where warmup is disk loads instead of XLA compiles.

Reported per scenario (all seconds from engine construction):

* ``time_to_first_request_s`` — build + (warmup) + one batch=1 request at
  the smallest grid shape, drained to host.
* ``time_to_full_throughput_s`` — ... + one drain per remaining grid cell
  (after it, no shape in the configured grid can hit a compile).
* compile-source counts (``fresh`` / ``disk`` / ``memory``) at both
  marks, plus ``request_path_fresh_compiles`` — fresh compiles paid
  *after* boot warmup, i.e. on the serving path.  The acceptance bar:
  AOT and cache-warm boots serve their first request with strictly fewer
  request-path fresh compiles than a cold boot (0 vs 1).

The persistent-cache config is process-global (``jax.config``), so the
cache-less scenarios run first and the cache dir is a tmpdir wiped at
exit.  All four engines live in one process: the in-process ``_jitted``
executable cache is per-engine, so a later scenario never reuses an
earlier scenario's executables — only the on-disk cache carries over,
which is exactly the effect under measurement.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks import common as C  # noqa: E402

from repro.serving import (  # noqa: E402
    BatchedSampler,
    SampleRequest,
    configure_persistent_cache,
)
from repro.serving import result_keys as K  # noqa: E402

BATCH_BUCKETS = (1, 2) if C.SMOKE else (1, 4, 8)
SEQ_BUCKETS = (4, 8) if C.SMOKE else (8, 16)
NFES = (5,) if C.SMOKE else (6, 10)


def _grid():
    return [
        (b, s, n) for n in NFES for s in SEQ_BUCKETS for b in BATCH_BUCKETS
    ]


def boot(mode: str, dlm, params) -> dict:
    """One engine boot under ``mode``'s warmup policy; returns the
    scenario record (see module docstring for the fields)."""
    t0 = time.perf_counter()
    engine = BatchedSampler(
        dlm, C.SCHEDULE,
        batch_buckets=BATCH_BUCKETS, seq_buckets=SEQ_BUCKETS,
    )
    build_s = time.perf_counter() - t0
    warm_rep = None
    if mode != "cold":
        warm_rep = engine.warmup(params, nfes=NFES)
    stats_boot = engine.compile_stats()

    grid = _grid()
    first = grid[0]
    seed = iter(range(1, len(grid) + 1))

    def serve(b, s, n):
        _, fut = engine.submit_with_future(
            SampleRequest(batch=b, seq_len=s, nfe=n, seed=next(seed))
        )
        engine.drain(params)
        fut.result()

    serve(*first)
    ttfr = time.perf_counter() - t0
    stats_ttfr = engine.compile_stats()
    for cell in grid[1:]:
        serve(*cell)
    ttft = time.perf_counter() - t0
    stats_ttft = engine.compile_stats()

    return {
        "mode": mode,
        "build_s": build_s,
        "warmup": warm_rep
        and {
            k: warm_rep[k]
            for k in ("programs", "fresh", "disk", "memory", K.WALL_S)
        },
        "time_to_first_request_s": ttfr,
        "time_to_full_throughput_s": ttft,
        "compiles_at_boot": stats_boot,
        "compiles_at_first_request": stats_ttfr,
        "compiles_at_full_throughput": stats_ttft,
        # fresh compiles the *serving path* paid (boot warmup excluded)
        "request_path_fresh_compiles": stats_ttfr["fresh"]
        - stats_boot["fresh"],
        "request_path_fresh_compiles_full": stats_ttft["fresh"]
        - stats_boot["fresh"],
    }


def run(out: str = "BENCH_coldstart.json") -> None:
    dlm, params, _, _ = C.trained_model(30 if C.SMOKE else 150)
    scenarios = []
    # order matters: the persistent-cache config is process-global, so the
    # cache-less boots must run before the cache dir is enabled
    for mode in ("cold", "aot"):
        scenarios.append(boot(mode, dlm, params))
    cache_dir = tempfile.mkdtemp(prefix="era_compile_cache_")
    try:
        configure_persistent_cache(cache_dir)
        for mode in ("cache_cold", "cache_warm"):
            scenarios.append(boot(mode, dlm, params))
    finally:
        # the cache config is process-global; leave no dangling pointer at
        # the wiped tmpdir for later suites in a benchmarks.run invocation
        import jax
        from jax._src import compilation_cache as _cc

        jax.config.update("jax_compilation_cache_dir", None)
        _cc.reset_cache()
        shutil.rmtree(cache_dir, ignore_errors=True)

    by_mode = {s["mode"]: s for s in scenarios}
    record = {
        "bench": "serving/coldstart",
        "smoke": C.SMOKE,
        "grid": {
            "batch_buckets": list(BATCH_BUCKETS),
            "seq_buckets": list(SEQ_BUCKETS),
            "nfes": list(NFES),
            "programs": len(_grid()),
        },
        "scenarios": scenarios,
    }

    for s in scenarios:
        C.emit(
            f"serving/coldstart/{s['mode']}/ttfr",
            s["time_to_first_request_s"] * 1e6,
            f"fresh_on_request_path={s['request_path_fresh_compiles']}",
        )
        C.emit(
            f"serving/coldstart/{s['mode']}/full",
            s["time_to_full_throughput_s"] * 1e6,
            f"fresh_on_request_path={s['request_path_fresh_compiles_full']}",
        )

    # acceptance: warmed boots must serve their first request with strictly
    # fewer request-path fresh compiles than a cold boot
    cold_fresh = by_mode["cold"]["request_path_fresh_compiles"]
    for mode in ("aot", "cache_warm"):
        if by_mode[mode]["request_path_fresh_compiles"] >= cold_fresh:
            print(
                f"# WARNING: {mode} boot paid "
                f"{by_mode[mode]['request_path_fresh_compiles']} fresh "
                f"compiles at first request (cold paid {cold_fresh}) — "
                f"warmup did not cover the grid"
            )
    warm = by_mode["cache_warm"]["warmup"]
    if warm and warm["disk"] == 0:
        print(
            "# WARNING: cache_warm warmup loaded 0 programs from the "
            "persistent cache — jax_compilation_cache_dir is not taking "
            "effect"
        )

    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_coldstart.json")
    run(ap.parse_args().out)


if __name__ == "__main__":
    main()
