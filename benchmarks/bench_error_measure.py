"""Paper Fig. 3: the sampling-time error measure delta_eps (Eq. 15) tracks
the true (injected / learned) noise-estimation error trend over steps."""

import jax
import numpy as np

from repro.core import ERAConfig, get_solver
from repro.serving import result_keys as K

from benchmarks import common as C


def run() -> None:
    mix = C.AnalyticMixture()
    xT = jax.random.normal(jax.random.PRNGKey(0), (256, 16))
    for scale in (0.0, 0.03, 0.08):
        out = get_solver("era")(
            mix.noisy(scale) if scale else mix.eps, xT, C.SCHEDULE,
            ERAConfig(nfe=20, k=4, error_norm="mean"),
        )
        hist = np.asarray(out.aux[K.DELTA_EPS_HISTORY])
        early = float(hist[4:9].mean())
        late = float(hist[-5:-1].mean())
        C.emit(
            f"fig3/noise{scale}", 0.0,
            f"delta_eps_early={early:.4f};delta_eps_late={late:.4f};"
            f"rising={late > early}",
        )


if __name__ == "__main__":
    run()
