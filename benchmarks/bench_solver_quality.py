"""Paper Tables 1/2/3/6 (FID vs NFE, per dataset) -> solver error vs NFE.

Setting A: analytic mixture oracle + injected late-time noise (the regime
the paper diagnoses in Fig. 1).  Setting B: in-repo trained diffusion-LM
(real learned error).  The paper's claim to reproduce: ERA-Solver wins at
low NFE (5-20) against DDIM / explicit Adams (PNDM) / DPM-Solver.
"""

import jax

from benchmarks import common as C

SOLVERS = ["ddim", "explicit_adams", "implicit_adams_pece",
           "dpm_solver_2", "dpm_solver_fast", "dpm_solver_pp2m", "era"]
NFES = [5, 10, 12, 15, 20, 40, 50]


def run() -> None:
    mix = C.AnalyticMixture()
    xT = jax.random.normal(jax.random.PRNGKey(0), (256, 16))

    settings = {
        "analytic-exact": mix.eps,
        "analytic-noisy": mix.noisy(0.03),
    }
    dlm, params, data, cfg = C.trained_model()
    xT_t = jax.random.normal(jax.random.PRNGKey(1), (64, 8, cfg.d_model))
    eps_t = dlm.eps_fn(params)

    for setting, eps_fn in settings.items():
        ref = C.reference_solution(mix.eps, xT)  # exact-ODE reference
        for solver in SOLVERS:
            for nfe in NFES:
                kw = {"k": 4, "lam": 5.0, "error_norm": "mean"} if solver == "era" else {}
                try:
                    x0 = C.solve(eps_fn, xT, solver, nfe, **kw)
                    err = C.rmse(x0, ref)
                except Exception as e:
                    err = float("nan")
                C.emit(f"table123/{setting}/{solver}/nfe{nfe}", 0.0,
                       f"rmse={err:.5f}")

    ref_t = C.reference_solution(eps_t, xT_t, nfe=400)
    for solver in SOLVERS:
        for nfe in NFES:
            kw = {"k": 3, "lam": 5.0, "error_norm": "mean"} if solver == "era" else {}
            x0 = C.solve(eps_t, xT_t, solver, nfe, **kw)
            C.emit(f"table123/trained/{solver}/nfe{nfe}", 0.0,
                   f"rmse={C.rmse(x0, ref_t):.5f}")


if __name__ == "__main__":
    run()
