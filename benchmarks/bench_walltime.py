"""Paper Table 7: sampling wall time by solver and NFE.  Also isolates the
solver overhead (Lagrange buffer + selection math) from network-eval time by
timing against a zero-cost eps function, and compares the fused Pallas ERA
step (the default) against the pure-jnp combine at serving batch sizes."""

import jax

from benchmarks import common as C
from repro.core import ERAConfig, get_solver


def run() -> None:
    dlm, params, data, cfg = C.trained_model(30 if C.SMOKE else 150)
    eps_fn = dlm.eps_fn(params)
    xT = jax.random.normal(jax.random.PRNGKey(0), (8, 8, cfg.d_model))

    nfes = (15,) if C.SMOKE else (15, 25, 50)
    for solver in ("ddim", "explicit_adams", "dpm_solver_fast", "era"):
        for nfe in nfes:
            kw = {"k": 4} if solver == "era" else {}
            fn = jax.jit(lambda x: C.solve(eps_fn, x, solver, nfe, **kw))
            dt = C.timer(fn, xT)
            C.emit(f"table7/{solver}/nfe{nfe}", dt * 1e6,
                   f"wall_s={dt:.4f}")

    # solver overhead alone: eps == identity (no network)
    null_eps = lambda x, t: x
    side = 64 if C.SMOKE else 256
    big = jax.random.normal(jax.random.PRNGKey(1), (4, side, side))
    for solver in ("ddim", "era"):
        kw = {"k": 4} if solver == "era" else {}
        fn = jax.jit(lambda x: C.solve(null_eps, x, solver, 20, **kw))
        dt = C.timer(fn, big)
        C.emit(f"table7/overhead/{solver}/nfe20", dt * 1e6,
               f"per_step_us={dt / 20 * 1e6:.1f}")

    # fused Pallas step (default) vs pure-jnp combine, serving batch sizes
    nfe = 8 if C.SMOKE else 20
    batch_sizes = (1, 8) if C.SMOKE else (1, 8, 64)
    for bs in batch_sizes:
        x = jax.random.normal(jax.random.PRNGKey(2), (bs, 8, cfg.d_model))
        for fused in (True, False):
            conf = ERAConfig(nfe=nfe, k=4, use_fused_update=fused)
            fn = jax.jit(
                lambda x, c=conf: get_solver("era")(
                    eps_fn, x, C.SCHEDULE, c
                ).x0
            )
            dt = C.timer(fn, x)
            tag = "fused" if fused else "jnp"
            C.emit(
                f"table7/step_path/{tag}/bs{bs}", dt * 1e6,
                f"per_req_ms={dt / bs * 1e3:.2f}",
            )


if __name__ == "__main__":
    run()
