"""Paper Table 7: sampling wall time by solver and NFE.  Also isolates the
solver overhead (Lagrange buffer + selection math) from network-eval time by
timing against a zero-cost eps function."""

import jax
import jax.numpy as jnp

from benchmarks import common as C


def run() -> None:
    dlm, params, data, cfg = C.trained_model()
    eps_fn = dlm.eps_fn(params)
    xT = jax.random.normal(jax.random.PRNGKey(0), (8, 8, cfg.d_model))

    for solver in ("ddim", "explicit_adams", "dpm_solver_fast", "era"):
        for nfe in (15, 25, 50):
            kw = {"k": 4} if solver == "era" else {}
            fn = jax.jit(lambda x: C.solve(eps_fn, x, solver, nfe, **kw))
            dt = C.timer(fn, xT)
            C.emit(f"table7/{solver}/nfe{nfe}", dt * 1e6,
                   f"wall_s={dt:.4f}")

    # solver overhead alone: eps == identity (no network)
    null_eps = lambda x, t: x
    big = jax.random.normal(jax.random.PRNGKey(1), (4, 256, 256))
    for solver in ("ddim", "era"):
        kw = {"k": 4} if solver == "era" else {}
        fn = jax.jit(lambda x: C.solve(null_eps, x, solver, 20, **kw))
        dt = C.timer(fn, big)
        C.emit(f"table7/overhead/{solver}/nfe20", dt * 1e6,
               f"per_step_us={dt / 20 * 1e6:.1f}")


if __name__ == "__main__":
    run()
