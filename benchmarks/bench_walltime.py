"""Paper Table 7: sampling wall time by solver and NFE.  Also isolates the
solver overhead (Lagrange buffer + selection math) from network-eval time by
timing against a zero-cost eps function, and compares the fused Pallas ERA
step (the default) against the pure-jnp combine at serving batch sizes.

``--masked-attn`` runs the masked-vs-unmasked attention sweep instead
(impls x masked/unmasked x seq buckets) and writes ``BENCH_maskedattn.json``
— the CI wall that mixed-seq-len kv_mask traffic stays on the fast kernels:
it FAILS if the masked Pallas path is absent from the sweep or any fast
impl fell back to chunked during it."""

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core import ERAConfig, get_solver


def run() -> None:
    dlm, params, data, cfg = C.trained_model(30 if C.SMOKE else 150)
    eps_fn = dlm.eps_fn(params)
    xT = jax.random.normal(jax.random.PRNGKey(0), (8, 8, cfg.d_model))

    nfes = (15,) if C.SMOKE else (15, 25, 50)
    for solver in ("ddim", "explicit_adams", "dpm_solver_fast", "era"):
        for nfe in nfes:
            kw = {"k": 4} if solver == "era" else {}
            fn = jax.jit(lambda x: C.solve(eps_fn, x, solver, nfe, **kw))
            dt = C.timer(fn, xT)
            C.emit(f"table7/{solver}/nfe{nfe}", dt * 1e6,
                   f"wall_s={dt:.4f}")

    # solver overhead alone: eps == identity (no network)
    null_eps = lambda x, t: x
    side = 64 if C.SMOKE else 256
    big = jax.random.normal(jax.random.PRNGKey(1), (4, side, side))
    for solver in ("ddim", "era"):
        kw = {"k": 4} if solver == "era" else {}
        fn = jax.jit(lambda x: C.solve(null_eps, x, solver, 20, **kw))
        dt = C.timer(fn, big)
        C.emit(f"table7/overhead/{solver}/nfe20", dt * 1e6,
               f"per_step_us={dt / 20 * 1e6:.1f}")

    # fused Pallas step (default) vs pure-jnp combine, serving batch sizes
    nfe = 8 if C.SMOKE else 20
    batch_sizes = (1, 8) if C.SMOKE else (1, 8, 64)
    for bs in batch_sizes:
        x = jax.random.normal(jax.random.PRNGKey(2), (bs, 8, cfg.d_model))
        for fused in (True, False):
            conf = ERAConfig(nfe=nfe, k=4, use_fused_update=fused)
            fn = jax.jit(
                lambda x, c=conf: get_solver("era")(
                    eps_fn, x, C.SCHEDULE, c
                ).x0
            )
            dt = C.timer(fn, x)
            tag = "fused" if fused else "jnp"
            C.emit(
                f"table7/step_path/{tag}/bs{bs}", dt * 1e6,
                f"per_req_ms={dt / bs * 1e3:.2f}",
            )


def run_masked_attention(out: str = "BENCH_maskedattn.json") -> None:
    """Masked-vs-unmasked attention sweep: impls x {masked, unmasked} x seq
    buckets, ragged per-row lengths, on the serving attention shapes.

    Acceptance (hard failures, not warnings):
      * the masked Pallas path must appear in the sweep, and
      * no fast impl (pallas / banded) may fire the chunked fallback while
        the sweep runs — that would mean masked traffic silently left the
        fast kernels, the regression ``sampler_masked_fallback_total``
        exists to catch.
    """
    from repro.models import attention as A

    b, h, kvh, hd = 4, 4, 2, 64
    buckets = (64, 128) if C.SMOKE else (128, 256, 512, 1024)
    fallbacks: list[tuple[str, str]] = []
    obs = A.register_fallback_observer(
        lambda impl, reason: fallbacks.append((impl, reason))
    )
    rows = []
    try:
        for s in buckets:
            key = jax.random.PRNGKey(s)
            kq, kk, kv_, kl = jax.random.split(key, 4)
            q = jax.random.normal(kq, (b, s, h, hd), jnp.float32)
            k = jax.random.normal(kk, (b, s, kvh, hd), jnp.float32)
            v = jax.random.normal(kv_, (b, s, kvh, hd), jnp.float32)
            pos = jnp.arange(s)
            # ragged mixed-length batch: one full row, the rest scattered
            lens = jax.random.randint(kl, (b,), s // 4, s + 1).at[0].set(s)
            mask = pos[None, :] < lens[:, None]
            for impl in ("pallas", "banded", "chunked"):
                # banded needs its layout (causal, windowed, s >= 4*window);
                # pallas/chunked run the denoiser layout (bidirectional)
                kw = (
                    dict(window=s // 4, causal=True, protected=2)
                    if impl == "banded"
                    else dict(window=0, causal=False)
                )
                for masked in (False, True):
                    fn = jax.jit(
                        lambda q, k, v, m, i=impl, kws=kw: A.sdpa(
                            q, k, v, pos, pos, impl=i, kv_mask=m, **kws
                        )
                    )
                    dt = C.timer(fn, q, k, v, mask if masked else None)
                    tag = "masked" if masked else "unmasked"
                    rows.append(
                        {
                            "impl": impl, "seq_bucket": s, "masked": masked,
                            "wall_us": dt * 1e6,
                        }
                    )
                    C.emit(
                        f"maskedattn/{impl}/s{s}/{tag}", dt * 1e6,
                        f"per_row_us={dt / b * 1e6:.1f}",
                    )
    finally:
        A.unregister_fallback_observer(obs)

    def wall(impl, s, masked):
        for r in rows:
            if (r["impl"], r["seq_bucket"], r["masked"]) == (impl, s, masked):
                return r["wall_us"]
        return None

    ratios = {}
    for s in buckets:
        for impl in ("pallas", "banded", "chunked"):
            m, u = wall(impl, s, True), wall(impl, s, False)
            if m and u:
                ratios[f"{impl}/s{s}/masked_over_unmasked"] = m / u
        pm, cm = wall("pallas", s, True), wall("chunked", s, True)
        if pm and cm:
            ratios[f"s{s}/masked_pallas_over_masked_chunked"] = pm / cm

    record = {
        "bench": "kernels/maskedattn",
        "smoke": C.SMOKE,
        "shape": {"batch": b, "heads": h, "kv_heads": kvh, "head_dim": hd},
        "seq_buckets": list(buckets),
        "sweep": rows,
        "fallbacks": [list(f) for f in fallbacks],
        "ratios": ratios,
    }
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out}")

    if not any(r["impl"] == "pallas" and r["masked"] for r in rows):
        raise SystemExit(
            "masked-attn sweep: masked Pallas path absent from the sweep"
        )
    fast_fallbacks = [f for f in fallbacks if f[0] in ("pallas", "banded")]
    if fast_fallbacks:
        raise SystemExit(
            f"masked-attn sweep: fast impls fell back to chunked: "
            f"{fast_fallbacks} — masked traffic left the fast kernels"
        )
    for name, r in ratios.items():
        if name.endswith("masked_over_unmasked") and r > 3.0:
            print(
                f"# WARNING: {name} = {r:.2f}x — masked path shows a "
                "walltime cliff vs unmasked"
            )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--masked-attn", action="store_true",
        help="run the masked-vs-unmasked attention sweep instead of Table 7",
    )
    ap.add_argument("--out", default="BENCH_maskedattn.json")
    args = ap.parse_args()
    if args.masked_attn:
        run_masked_attention(args.out)
    else:
        run()


if __name__ == "__main__":
    main()
