"""Paper Tables 4/5: error-robust selection (ERS) vs fixed last-k selection
across Lagrange orders k=3..6.  Claim: ERS >= fixed everywhere, and fixed
explodes at k=5,6 while ERS stays stable."""

import jax

from benchmarks import common as C


def run() -> None:
    mix = C.AnalyticMixture()
    noisy = mix.noisy(0.03)
    xT = jax.random.normal(jax.random.PRNGKey(0), (256, 16))
    ref = C.reference_solution(mix.eps, xT)

    for k in (3, 4, 5, 6):
        for sel in ("fixed", "ers"):
            for nfe in (10, 15, 20, 50):
                x0 = C.solve(
                    noisy, xT, "era", nfe,
                    k=k, lam=5.0, selection=sel, error_norm="mean",
                )
                C.emit(f"table45/k{k}/{sel}/nfe{nfe}", 0.0,
                       f"rmse={C.rmse(x0, ref):.5f}")


if __name__ == "__main__":
    run()
