"""Roofline analysis (deliverable g): derive compute / memory / collective
terms per (arch x shape x mesh) from the dry-run artifacts.

    compute term    = loop-aware HLO dot FLOPs / peak_FLOPs        [s]
    memory term     = modeled HBM bytes / HBM_bw                   [s]
    collective term = collective bytes / (links x ICI_bw)          [s]

All quantities are per device (the compiled module is the per-device SPMD
program).  Modeled HBM bytes = dot operand/result traffic + argument bytes
(params/optimizer/cache read+write) — the unfused raw byte count from CPU
HLO is reported alongside as an upper bound (TPU XLA fuses elementwise
chains; CPU HLO text does not reflect that).

MODEL_FLOPS uses 6*N*D (dense) / 6*N_active*D (MoE) for train (fwd+bwd),
2*N*D for single forwards, so MODEL/HLO ratio ~1/1.33 signals an efficient
program for inference/train (train has +remat recompute => expect ~0.75).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16
from repro.models import build_model

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
ART_OPT = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun_opt")
ICI_LINKS = 4  # 2D torus on v5e: 4 links per chip


def active_param_fraction(cfg) -> float:
    """Fraction of FFN params active per token (MoE top-k routing)."""
    if cfg.moe is None:
        return 1.0
    m = cfg.moe
    total_ff = m.num_experts + m.num_shared
    active_ff = m.top_k + m.num_shared
    # approximate: FFN params dominate expert-parallel archs
    model = build_model(cfg)
    n = model.param_count()
    ffn_per_layer = 3 * cfg.d_model * m.d_ff_expert
    routed = cfg.num_layers * m.num_experts * ffn_per_layer
    active = n - routed + cfg.num_layers * m.top_k * ffn_per_layer
    return active / n


def matmul_param_count(cfg) -> int:
    """Params participating in matmuls (excludes lookup-only tables like
    Whisper's 524k-position embedding, which would inflate 6ND)."""
    from repro.models.model import model_specs
    from repro.models import layers as L

    specs = model_specs(cfg)
    total = L.count_params(specs)
    if "pos_embed" in specs:
        import math
        total -= math.prod(specs["pos_embed"].shape)
    return total


def model_flops(cfg, shape, n_params: int) -> float:
    """Analytic 'useful' FLOPs for the whole step (global)."""
    frac = active_param_fraction(cfg)
    n_active = n_params * frac
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 8.0 * n_active * tokens  # fwd+bwd+remat-extra-fwd ~ 8ND
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


def load_records(mesh: str = "single", art: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art or ART, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def analyze_record(rec: dict) -> dict | None:
    if not rec.get("ok") or "hlo" not in rec:
        return None
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    n_dev = rec["num_devices"]
    hlo = rec["hlo"]
    ma = rec.get("memory_analysis", {})

    flops_dev = hlo["flops"]
    arg_bytes = ma.get("argument_size_in_bytes", 0) + ma.get(
        "output_size_in_bytes", 0
    )
    hbm_model = hlo.get("dot_bytes", 0.0) + arg_bytes
    coll_bytes = hlo.get("collective_bytes_total", 0.0)

    compute_t = flops_dev / PEAK_FLOPS_BF16
    memory_t = hbm_model / HBM_BW
    coll_t = coll_bytes / (ICI_LINKS * ICI_BW_PER_LINK)
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)

    n_params = matmul_param_count(cfg)
    mf = model_flops(cfg, shape, n_params) / n_dev
    ratio = mf / flops_dev if flops_dev else float("nan")

    temp = ma.get("temp_size_in_bytes", 0)
    fits = (temp + ma.get("argument_size_in_bytes", 0)) <= 16e9

    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "entry": rec["entry"],
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops_dev,
        "useful_ratio": ratio,
        "temp_gb": temp / 1e9,
        "args_gb": ma.get("argument_size_in_bytes", 0) / 1e9,
        "fits_16gb": fits,
        "hbm_raw_bytes": hlo.get("hbm_bytes", 0.0),
        "collectives": hlo.get("collectives", {}),
    }


def run(mesh: str = "single") -> list[dict]:
    rows = []
    variants = [("baseline", ART)]
    if glob.glob(os.path.join(ART_OPT, f"*__{mesh}.json")):
        variants.append(("optimized", ART_OPT))
    for label, art in variants:
        for rec in load_records(mesh, art):
            row = analyze_record(rec)
            if row is None:
                print(f"roofline-{label}/{rec.get('arch')}/{rec.get('shape')},0.0,MISSING")
                continue
            row["variant"] = label
            rows.append(row)
            print(
                f"roofline-{label}/{row['arch']}/{row['shape']},0.0,"
                f"compute_s={row['compute_s']:.3e};memory_s={row['memory_s']:.3e};"
                f"collective_s={row['collective_s']:.3e};dominant={row['dominant']};"
                f"useful={row['useful_ratio']:.2f};temp_gb={row['temp_gb']:.1f};"
                f"fits={row['fits_16gb']}"
            )
    return rows


if __name__ == "__main__":
    run()
