"""HTTP front door for the sampling engine — the network wire path.

Turns the in-process serving stack into an actual service: a
:class:`FrontDoor` wraps an
:class:`~repro.serving.scheduler.AsyncBatchedSampler` with a stdlib
``ThreadingHTTPServer`` (no new dependencies) speaking a **versioned JSON
schema** that round-trips exactly the
:class:`~repro.serving.executor.SampleRequest` /
:class:`~repro.serving.executor.SampleResult` dataclass pair — no
parallel wire types.  Endpoints:

* ``POST /v1/sample`` — submit one :class:`SampleRequest`; blocks the
  connection's handler thread until the result is drained, then returns
  the encoded :class:`SampleResult`.  Arrays travel as base64-encoded raw
  buffers (dtype + shape + bytes), so a wire result is **bit-identical**
  to the in-process one.  Admission control maps
  :class:`~repro.serving.scheduler.QueueFullError` to **429** with a
  ``Retry-After`` header; an expired ``deadline_ms`` maps to **504** with
  a typed ``deadline_exceeded`` error; validation failures map to **400**.
* ``GET /metrics`` — the engine's Prometheus text exposition
  (:mod:`repro.serving.metrics`): queue depth per fuse group, fuse
  occupancy, compile source counts (memory/disk/fresh) and warmup
  progress, admission rejects, deadline expirations, arrival-to-result
  latency histogram, HTTP request counts.
* ``GET /healthz`` — pure **liveness** + scheduler stats as JSON: 200 as
  soon as the listener is up, even while programs are still compiling.
  Wire an LB's health check here only to detect dead processes.
* ``GET /readyz`` — **readiness**: 503 with warmup progress JSON until
  the AOT warmup grid is compiled, 200 after (immediately, when the front
  door was built without a warmup).  Point traffic routing here, so a
  replica only receives requests once they won't eat a multi-second
  compile.

:class:`FrontDoorClient` is the matching stdlib client (used by
``launch/serve.py --connect`` and ``bench_serving --frontdoor``); it maps
the typed wire errors back to the same exception classes the in-process
scheduler raises, so retry logic is transport-agnostic.

Error responses are JSON: ``{"v": 1, "error": {"type": ..., "message":
...}}`` with ``type`` one of ``invalid_request`` / ``queue_full`` /
``deadline_exceeded`` / ``not_found`` / ``internal``.
"""

from __future__ import annotations

import base64
import dataclasses
import json
import math
import threading
from http.client import HTTPConnection
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import urlsplit

import numpy as np

from repro.serving.executor import SampleRequest, SampleResult
from repro.serving.scheduler import (
    AsyncBatchedSampler,
    DeadlineExceededError,
    QueueFullError,
)

#: wire schema version; bump on any incompatible request/response change
SCHEMA_VERSION = 1

METRICS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_REQUEST_FIELDS = {f.name: f for f in dataclasses.fields(SampleRequest)}
_RESULT_FIELDS = {f.name: f for f in dataclasses.fields(SampleResult)}
_INT_FIELDS = ("batch", "seq_len", "nfe", "seed", "priority")


class SchemaError(ValueError):
    """The payload does not conform to the versioned wire schema."""


# ---------------------------------------------------------------------------
# wire schema: SampleRequest / SampleResult <-> JSON
# ---------------------------------------------------------------------------


def encode_array(x) -> dict:
    """Array -> JSON-safe dict.  Raw little-endian bytes in base64 (not
    decimal strings), so decode is bit-exact for every dtype."""
    a = np.ascontiguousarray(np.asarray(x))
    return {
        "__nd__": True,
        "dtype": a.dtype.str,  # byte-order explicit, e.g. "<f4"
        "shape": list(a.shape),
        "data": base64.b64encode(a.tobytes()).decode("ascii"),
    }


def decode_array(d: dict) -> np.ndarray:
    if not (isinstance(d, dict) and d.get("__nd__")):
        raise SchemaError(f"expected an encoded array, got {type(d).__name__}")
    buf = base64.b64decode(d["data"])
    return np.frombuffer(buf, dtype=np.dtype(d["dtype"])).reshape(d["shape"])


def _check_version(payload) -> dict:
    if not isinstance(payload, dict):
        raise SchemaError(
            f"payload must be a JSON object, got {type(payload).__name__}"
        )
    v = payload.get("v")
    if v != SCHEMA_VERSION:
        raise SchemaError(
            f"unsupported schema version {v!r}; this endpoint speaks "
            f"v={SCHEMA_VERSION}"
        )
    return {k: payload[k] for k in payload if k != "v"}


def encode_request(req: SampleRequest) -> dict:
    """``SampleRequest`` -> versioned JSON body (exactly its fields)."""
    return {"v": SCHEMA_VERSION, **dataclasses.asdict(req)}


def decode_request(payload) -> SampleRequest:
    """Versioned JSON body -> ``SampleRequest``.

    Rejects (``SchemaError``): wrong/missing ``v``, unknown fields (a
    misspelled ``prioritty`` must not silently sample at default
    priority), and non-numeric/non-string field types.  Range validation
    (batch >= 1, known solver, deadline > 0, ...) stays where it lives for
    in-process callers: ``FusedExecutor.validate`` at submit.
    """
    body = _check_version(payload)
    unknown = set(body) - set(_REQUEST_FIELDS)
    if unknown:
        raise SchemaError(
            f"unknown request fields {sorted(unknown)}; the v{SCHEMA_VERSION} "
            f"schema has {sorted(_REQUEST_FIELDS)}"
        )
    for name in _INT_FIELDS:
        if name in body and (
            isinstance(body[name], bool) or not isinstance(body[name], int)
        ):
            raise SchemaError(f"field {name!r} must be an integer")
    if "solver" in body and not (
        body["solver"] is None or isinstance(body["solver"], str)
    ):
        raise SchemaError("field 'solver' must be a string or null")
    if "deadline_ms" in body and not (
        body["deadline_ms"] is None
        or (
            isinstance(body["deadline_ms"], (int, float))
            and not isinstance(body["deadline_ms"], bool)
        )
    ):
        raise SchemaError("field 'deadline_ms' must be a number or null")
    try:
        return SampleRequest(**body)
    except TypeError as e:  # missing required fields
        raise SchemaError(str(e)) from None


def _encode_value(v):
    if hasattr(v, "shape"):
        return encode_array(v)
    if isinstance(v, dict):
        return {k: _encode_value(x) for k, x in v.items()}
    return v


def _decode_value(v):
    if isinstance(v, dict):
        if v.get("__nd__"):
            return decode_array(v)
        return {k: _decode_value(x) for k, x in v.items()}
    return v


def encode_result(res: SampleResult) -> dict:
    """``SampleResult`` -> versioned JSON body.  Field-generic over the
    dataclass (the wire schema IS the dataclass, no parallel type); arrays
    — including inside ``aux`` — go base64, scalars pass through."""
    return {
        "v": SCHEMA_VERSION,
        **{f: _encode_value(getattr(res, f)) for f in _RESULT_FIELDS},
    }


def decode_result(payload) -> SampleResult:
    """Versioned JSON body -> ``SampleResult`` with numpy arrays (bit-
    identical to the server-side result).  Unknown fields are rejected —
    the client must not silently drop data a newer server sent."""
    body = _check_version(payload)
    unknown = set(body) - set(_RESULT_FIELDS)
    if unknown:
        raise SchemaError(
            f"unknown result fields {sorted(unknown)}; the v{SCHEMA_VERSION} "
            f"schema has {sorted(_RESULT_FIELDS)}"
        )
    missing = set(_RESULT_FIELDS) - set(body)
    if missing:
        raise SchemaError(f"missing result fields {sorted(missing)}")
    return SampleResult(**{f: _decode_value(v) for f, v in body.items()})


def encode_error(kind: str, message: str) -> dict:
    return {"v": SCHEMA_VERSION, "error": {"type": kind, "message": message}}


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------


class FrontDoor:
    """HTTP server over an :class:`AsyncBatchedSampler`.

    One handler thread per connection (``ThreadingHTTPServer``); a
    ``POST /v1/sample`` handler blocks on the request's Future while the
    scheduler's drain thread fuses and runs batches — so concurrent wire
    requests batch together exactly like in-process submits.

    ``port=0`` binds an ephemeral port (tests);  :attr:`url` reports the
    bound address.  ``idle_timeout_s`` bounds how long a keep-alive
    connection may sit idle (or trickle a request) before its handler
    thread is reclaimed — it never limits an in-flight sample, which
    blocks on the scheduler Future, not the socket (``None`` = no
    timeout, trusted clients only).  ``start()``/``stop()`` (or use as a context manager)
    run the accept loop on a daemon thread; ``stop()`` also stops the
    scheduler when the front door owns it
    (:func:`serve_frontdoor` sets that up).

    ``warmup`` (a zero-arg callable, typically
    ``lambda: scheduler.warmup(...)``) gates readiness: ``start()`` runs
    it on a background daemon thread — the listener binds and ``/healthz``
    answers immediately — and ``/readyz`` serves 503 with
    ``scheduler.warmup_status()`` progress until it returns, 200 after.
    If it raises, the replica stays NOT ready and ``/readyz`` carries the
    error (a failed warmup on a broken build must not attract traffic).
    ``None`` (default) = ready from the first byte.
    """

    def __init__(
        self,
        scheduler: AsyncBatchedSampler,
        host: str = "127.0.0.1",
        port: int = 0,
        owns_scheduler: bool = False,
        idle_timeout_s: float | None = 30.0,
        warmup=None,
    ):
        self.scheduler = scheduler
        self._owns_scheduler = owns_scheduler
        self._warmup_fn = warmup
        self._warmup_thread: threading.Thread | None = None
        self._warmup_error: str | None = None
        self._ready = threading.Event()
        if warmup is None:
            self._ready.set()
        self._m_http = scheduler.engine.metrics.counter(
            "frontdoor_http_requests_total",
            "HTTP requests served, by route and status code",
        )
        frontdoor = self

        class Handler(BaseHTTPRequestHandler):
            # Socket timeout for *reading* a request (the next request
            # line on a keep-alive connection, or a trickling body).
            # Without one, every idle persistent connection pins a
            # handler thread forever — an unbounded thread/socket leak
            # for any client that doesn't close per request.  The
            # in-flight sample wait is unaffected: the handler blocks on
            # the scheduler Future, not the socket, so a fused batch may
            # take arbitrarily long.  http.server turns a timed-out read
            # into close_connection, ending the handler cleanly.
            timeout = idle_timeout_s
            protocol_version = "HTTP/1.1"

            def log_message(self, fmt, *args):  # noqa: A003 - BaseHTTP API
                pass  # metrics, not stderr spam

            def do_GET(self):  # noqa: N802 - BaseHTTP API
                frontdoor._handle(self, "GET")

            def do_POST(self):  # noqa: N802 - BaseHTTP API
                frontdoor._handle(self, "POST")

        self._server = ThreadingHTTPServer((host, port), Handler)
        self._server.daemon_threads = True
        self._thread: threading.Thread | None = None

    # ---- lifecycle ------------------------------------------------------
    @property
    def host(self) -> str:
        return self._server.server_address[0]

    @property
    def port(self) -> int:
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "FrontDoor":
        if self._thread is not None:
            raise RuntimeError("front door already started")
        self._thread = threading.Thread(
            target=self._server.serve_forever,
            name="era-frontdoor",
            daemon=True,
        )
        self._thread.start()
        if self._warmup_fn is not None and self._warmup_thread is None:
            # warm in the background: the listener is already accepting, so
            # /healthz (liveness) answers during the compile wall and
            # /readyz flips 503 -> 200 when the grid is in
            self._warmup_thread = threading.Thread(
                target=self._run_warmup, name="era-warmup", daemon=True
            )
            self._warmup_thread.start()
        return self

    def _run_warmup(self) -> None:
        try:
            self._warmup_fn()
        except Exception as e:  # noqa: BLE001 - surfaced via /readyz
            self._warmup_error = f"{type(e).__name__}: {e}"
        else:
            self._ready.set()

    @property
    def ready(self) -> bool:
        """Has the boot warmup finished (or was none configured)?"""
        return self._ready.is_set()

    def readiness(self) -> dict:
        """The ``/readyz`` payload: ``ready`` flag + the scheduler's
        warmup progress (+ ``error`` if the warmup raised)."""
        payload = {
            "v": SCHEMA_VERSION,
            "ready": self.ready,
            "warmup": self.scheduler.warmup_status(),
        }
        if self._warmup_error is not None:
            payload["error"] = self._warmup_error
        return payload

    def stop(self) -> None:
        """Stop accepting, join the accept loop, and (when owning it)
        stop the scheduler — which flushes every queued request."""
        if self._thread is not None:
            self._server.shutdown()
            self._thread.join()
            self._thread = None
        self._server.server_close()
        if self._owns_scheduler:
            self.scheduler.stop()

    def __enter__(self) -> "FrontDoor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- request handling ----------------------------------------------
    def _handle(self, handler: BaseHTTPRequestHandler, method: str) -> None:
        route = urlsplit(handler.path).path
        handler._response_started = False  # set by _respond_text
        try:
            if method == "POST" and route == "/v1/sample":
                self._handle_sample(handler, route)
            elif method == "GET" and route == "/metrics":
                self._respond_text(
                    handler, route, 200,
                    self.scheduler.engine.metrics.render(),
                    METRICS_CONTENT_TYPE,
                )
            elif method == "GET" and route == "/healthz":
                # pure liveness: 200 from the first byte, even mid-warmup
                self._respond_json(
                    handler, route, 200,
                    {"v": SCHEMA_VERSION, "ok": True,
                     "stats": self.scheduler.stats()},
                )
            elif method == "GET" and route == "/readyz":
                payload = self.readiness()
                self._respond_json(
                    handler, route, 200 if payload["ready"] else 503, payload
                )
            else:
                self._respond_json(
                    handler, route, 404,
                    encode_error("not_found", f"no route {method} {route}"),
                )
        except BrokenPipeError:
            pass  # client hung up mid-response; nothing to deliver to
        except Exception as e:  # noqa: BLE001 - must answer, not crash
            if handler._response_started:
                # a response (possibly a 200) was partially written:
                # appending a 500 status line here would corrupt the HTTP
                # stream on this connection — just drop the connection so
                # the client sees a truncated response, not a forged one
                handler.close_connection = True
                return
            try:
                self._respond_json(
                    handler, route, 500, encode_error("internal", str(e))
                )
            except Exception:  # noqa: BLE001 - socket already gone
                pass

    def _handle_sample(self, handler, route: str) -> None:
        length = int(handler.headers.get("Content-Length") or 0)
        raw = handler.rfile.read(length) if length else b""
        try:
            payload = json.loads(raw.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            self._respond_json(
                handler, route, 400,
                encode_error("invalid_request", f"body is not JSON: {e}"),
            )
            return
        try:
            req = decode_request(payload)
            fut = self.scheduler.submit(req)
        except (SchemaError, ValueError) as e:
            self._respond_json(
                handler, route, 400, encode_error("invalid_request", str(e))
            )
            return
        except QueueFullError as e:
            self._respond_json(
                handler, route, 429, encode_error("queue_full", str(e)),
                headers={"Retry-After": str(max(1, math.ceil(e.retry_after_s)))},
            )
            return
        try:
            res = fut.result()
        except DeadlineExceededError as e:
            self._respond_json(
                handler, route, 504, encode_error("deadline_exceeded", str(e))
            )
            return
        except Exception as e:  # noqa: BLE001 - chunk failure -> typed 500
            self._respond_json(
                handler, route, 500, encode_error("internal", str(e))
            )
            return
        self._respond_json(handler, route, 200, encode_result(res))

    # ---- response plumbing ----------------------------------------------
    def _respond_text(
        self, handler, route, code, text: str, content_type: str,
        headers: dict | None = None,
    ) -> None:
        body = text.encode("utf-8")
        # from here on a failure must not trigger a second status line
        handler._response_started = True
        handler.send_response(code)
        handler.send_header("Content-Type", content_type)
        handler.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            handler.send_header(k, v)
        handler.end_headers()
        handler.wfile.write(body)
        self._m_http.inc(route=route, code=str(code))

    def _respond_json(
        self, handler, route, code, payload: dict,
        headers: dict | None = None,
    ) -> None:
        self._respond_text(
            handler, route, code, json.dumps(payload),
            "application/json", headers,
        )


def serve_frontdoor(
    engine,
    params,
    policy=None,
    host: str = "127.0.0.1",
    port: int = 0,
    warmup=None,
) -> FrontDoor:
    """One-call server bring-up: start a scheduler over ``engine`` and a
    :class:`FrontDoor` that owns it.  ``stop()`` on the returned front
    door tears both down (flushing queued requests).

    ``warmup`` gates ``/readyz`` (see :class:`FrontDoor`): a dict is
    keyword arguments for the scheduler's AOT grid warmup
    (``scheduler.warmup(solvers=..., seq_lens=..., nfes=...)`` — what
    :func:`~repro.serving.factory.warmup_kwargs` produces), a callable is
    run as-is, ``None`` means ready immediately.  Either way the warmup
    runs on a background thread, so this returns as soon as the listener
    is bound."""
    scheduler = AsyncBatchedSampler(engine, params, policy).start()
    warmup_fn = warmup
    if isinstance(warmup, dict):
        kw = dict(warmup)

        def warmup_fn():
            return scheduler.warmup(**kw)

    try:
        return FrontDoor(
            scheduler, host=host, port=port, owns_scheduler=True,
            warmup=warmup_fn,
        ).start()
    except Exception:
        scheduler.stop()
        raise


# ---------------------------------------------------------------------------
# client
# ---------------------------------------------------------------------------


class FrontDoorClient:
    """Stdlib HTTP client for the front door.

    ``sample()`` re-raises the server's typed errors as the same exception
    classes the in-process scheduler uses (:class:`QueueFullError` with
    ``retry_after_s`` from the header, :class:`DeadlineExceededError`,
    ``ValueError`` for 400s), so callers keep one error-handling path for
    loopback and wire.  One connection per call — handlers block for the
    whole sample, so pooling would just pin sockets.
    """

    def __init__(self, base_url: str, timeout: float | None = None):
        parts = urlsplit(base_url)
        if parts.scheme != "http" or not parts.netloc:
            raise ValueError(
                f"base_url must be http://host:port, got {base_url!r}"
            )
        self._netloc = parts.netloc
        self._timeout = timeout

    def _request(self, method: str, path: str, body: bytes | None = None):
        conn = HTTPConnection(self._netloc, timeout=self._timeout)
        try:
            headers = {"Content-Type": "application/json"} if body else {}
            conn.request(method, path, body=body, headers=headers)
            resp = conn.getresponse()
            return resp.status, dict(resp.getheaders()), resp.read()
        finally:
            conn.close()

    @staticmethod
    def _error_payload(raw: bytes) -> dict:
        try:
            payload = json.loads(raw.decode("utf-8"))
            return payload.get("error") or {}
        except (UnicodeDecodeError, json.JSONDecodeError):
            return {}

    def sample(self, req: SampleRequest) -> SampleResult:
        """POST the request; block until the wire result arrives, decoded
        back into a :class:`SampleResult` (numpy ``x0``/``aux``)."""
        body = json.dumps(encode_request(req)).encode("utf-8")
        status, headers, raw = self._request("POST", "/v1/sample", body)
        if status == 200:
            return decode_result(json.loads(raw.decode("utf-8")))
        err = self._error_payload(raw)
        message = err.get("message", f"HTTP {status}")
        # reconstructed exceptions carry the *server's* message: the queue
        # key / row counts / waited time live server-side, so the
        # placeholder attributes here (key=None, waited_ms=nan) must not
        # leak into what retry paths log
        if status == 429:
            retry = float(headers.get("Retry-After", "1"))
            raise QueueFullError(
                key=None, rows=-1, limit=-1, retry_after_s=retry,
                message=message,
            )
        if status == 504:
            raise DeadlineExceededError(
                req, waited_ms=float("nan"), message=message
            )
        if status == 400:
            raise ValueError(message)
        raise RuntimeError(f"front door error {status}: {message}")

    def metrics(self) -> str:
        status, _, raw = self._request("GET", "/metrics")
        if status != 200:
            raise RuntimeError(f"/metrics returned HTTP {status}")
        return raw.decode("utf-8")

    def healthz(self) -> dict:
        """GET /healthz — pure liveness (200 even while warming up)."""
        status, _, raw = self._request("GET", "/healthz")
        if status != 200:
            raise RuntimeError(f"/healthz returned HTTP {status}")
        return json.loads(raw.decode("utf-8"))

    def readyz(self) -> dict:
        """GET /readyz — the readiness payload.  A 503 (still warming, or
        warmup failed) is a *state*, not a transport error, so both 200
        and 503 return the parsed payload — check ``payload["ready"]``;
        any other status raises."""
        status, _, raw = self._request("GET", "/readyz")
        if status not in (200, 503):
            raise RuntimeError(f"/readyz returned HTTP {status}")
        payload = json.loads(raw.decode("utf-8"))
        payload["ready"] = bool(payload.get("ready")) and status == 200
        return payload
