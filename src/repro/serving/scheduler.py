"""Continuous-batching async scheduler for the diffusion sampling engine.

The sync :class:`~repro.serving.diffusion_sampler.BatchedSampler` only fuses
requests that happen to be pending at the same ``drain()`` call, so a steady
open-loop request stream degenerates to batch-of-1 drains and wastes the
fused step and mesh sharding.  :class:`AsyncBatchedSampler` fixes that with
the standard continuous-batching shape for fixed-cost (known-NFE) solvers:

* ``submit()`` is callable from any thread and returns a
  :class:`concurrent.futures.Future` that resolves to a
  :class:`~repro.serving.executor.SampleResult`;
* requests land in per-(solver, seq, nfe) queues — the executor's group
  key, where ``seq`` is the request's seq *bucket* when the engine does
  mixed-seq-len fusion (the exact ``seq_len`` otherwise), and ``nfe`` is
  likewise the request's NFE *bucket* when the engine does mixed-NFE
  fusion (the exact ``nfe`` otherwise).  Only same-group requests can
  fuse into one compiled bucket: a mixed ``era`` / ``ddim`` / ... stream
  batches per solver instead of cross-contaminating a bucket, while
  (under seq / nfe bucketing) requests of *different* lengths and step
  budgets share a queue, a batch, and a compiled program;
* a background drain thread launches a queue when it reaches the policy's
  target bucket occupancy, or when its oldest request has waited
  ``max_wait_ms`` (deadline promotion — a lone request can never starve);
* ready queues are served highest-priority-first (a queue's priority is
  its most urgent pending request's), then oldest-request-first; within a
  queue, higher-``priority`` requests board a launch first (FIFO among
  equal priorities), and each launch takes at most one largest-bucket's
  worth of rows (the rest keep their original arrival times for the next
  launch).

**Admission control** (``SchedulerPolicy.max_queue_rows``): each
fuse-group queue is bounded — a ``submit()`` that would push a queue past
the limit raises :class:`QueueFullError` immediately (the front door maps
it to HTTP 429 + ``Retry-After``) instead of growing an unbounded backlog.
**Deadlines** (``SampleRequest.deadline_ms``): a request still queued past
its deadline fails fast with :class:`DeadlineExceededError` at the next
drain pass — it never occupies a seat in a fused batch it can no longer
use.  Both are pure queue policy: neither affects any admitted request's
results.

Execution goes through the same thread-safe
:class:`~repro.serving.executor.FusedExecutor` as the sync path, so the
compiled-bucket cache, mesh placement, and per-sample ERS isolation are
shared — a request's ``x0`` is bit-identical whether it runs via sync
``drain()``, via this scheduler under any arrival interleaving, or solo.

All policy decisions read an injectable ``clock`` and are reachable via
:meth:`AsyncBatchedSampler.drain_once`, so the scheduling logic is testable
with a fake clock and no background thread or real sleeps.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Callable

from repro.serving import result_keys as K
from repro.serving.diffusion_sampler import BatchedSampler
from repro.serving.executor import (
    QueueItem,
    SampleRequest,
    SampleResult,
    resolve_future,
)


class QueueFullError(RuntimeError):
    """Admission control rejected a submit: the request's fuse-group queue
    is at ``SchedulerPolicy.max_queue_rows``.  ``retry_after_s`` is the
    server's backoff hint (the front door sends it as ``Retry-After``).

    ``message`` overrides the formatted text — the wire client rebuilds
    this exception from a 429 whose body carries the *server's* message
    (the client has no queue key or row counts of its own), so the
    override keeps remote diagnostics as informative as in-process ones.
    """

    def __init__(
        self,
        key,
        rows: int,
        limit: int,
        retry_after_s: float,
        message: str | None = None,
    ):
        self.key = key
        self.rows = rows
        self.limit = limit
        self.retry_after_s = retry_after_s
        super().__init__(
            message
            if message is not None
            else f"queue {key} is full ({rows} rows >= limit {limit}); "
            f"retry in {retry_after_s:.1f}s"
        )


class DeadlineExceededError(RuntimeError):
    """A request spent longer than its ``deadline_ms`` in the queue and was
    failed fast instead of boarding a fused batch it can no longer use.

    ``message`` overrides the formatted text — the wire client rebuilds
    this exception from a 504 whose body carries the server's message
    (including the actual waited time, which the client cannot know).
    """

    def __init__(
        self, req: SampleRequest, waited_ms: float, message: str | None = None
    ):
        self.req = req
        self.waited_ms = waited_ms
        super().__init__(
            message
            if message is not None
            else f"request (seed={req.seed}, "
            f"solver={req.solver or 'default'}) "
            f"expired in queue: waited {waited_ms:.1f}ms > "
            f"deadline_ms={req.deadline_ms:g}"
        )


def open_loop(gaps, emit, clock=time.perf_counter, sleep=time.sleep) -> float:
    """Drive an open-loop client: call ``emit(i)`` at each cumulative
    arrival offset of ``gaps``.  Sleeps only while ahead of schedule and
    catches up by emitting back-to-back when behind — a per-arrival sleep
    would floor the deliverable rate at the timer resolution.  When behind,
    ``sleep(0)`` still runs so a client colocated with the drain thread
    yields the interpreter instead of contending with it.  Returns the
    stream start time (same ``clock``), for makespan accounting.
    """
    t_start = clock()
    offset = 0.0
    for i, gap in enumerate(gaps):
        offset += gap
        delay = t_start + offset - clock()
        sleep(delay if delay > 0 else 0.0)
        emit(i)
    return t_start


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    """When does a queue of compatible requests launch as one fused batch?

    * ``max_wait_ms`` — upper bound on how long any request waits in the
      queue before its shape group is force-launched (deadline promotion).
      Lower = better p99 latency, higher = fuller batches / more throughput.
    * ``target_occupancy`` — fraction of the engine's largest batch bucket
      at which a queue launches immediately instead of waiting out the
      deadline.  1.0 waits for a completely full bucket; 0.25 launches as
      soon as a quarter-bucket of rows is pending.
    * ``max_queue_rows`` — admission bound per fuse-group queue: a submit
      that would push a queue's pending rows past this raises
      :class:`QueueFullError` (HTTP 429 at the front door) instead of
      queueing.  ``None`` = unbounded (in-process callers that manage
      their own backpressure).
    """

    max_wait_ms: float = 10.0
    target_occupancy: float = 1.0
    max_queue_rows: int | None = None

    def target_rows(self, max_bucket: int | None) -> int | None:
        """Row count that triggers an immediate launch (None = deadline
        only, for engines with no batch buckets)."""
        if max_bucket is None:
            return None
        return max(1, math.ceil(self.target_occupancy * max_bucket))

    def deadline(self, oldest_t: float) -> float:
        return oldest_t + self.max_wait_ms / 1e3

    def should_launch(
        self, now: float, oldest_t: float, rows: int, max_bucket: int | None
    ) -> bool:
        target = self.target_rows(max_bucket)
        if target is not None and rows >= target:
            return True
        return now >= self.deadline(oldest_t)

    def retry_after_s(self) -> float:
        """Backoff hint for an admission-rejected client: by the time one
        launch deadline has passed, the rejected queue has had a chance to
        drain at least once."""
        return max(1.0, self.max_wait_ms / 1e3)


class AsyncBatchedSampler:
    """Continuous-batching front end over a :class:`BatchedSampler`.

    ``submit()`` from any thread; a background drain thread (``start()`` /
    ``stop()``, or use as a context manager) fuses requests across arrival
    time through the engine's shared
    :class:`~repro.serving.executor.FusedExecutor`.

    Thread-safety and blocking behavior: ``submit`` / ``pending`` /
    ``stats`` are non-blocking and callable from any thread (results are
    delivered through futures); execution happens on the drain thread, or
    on the caller's thread for explicit ``drain_once()`` pumping.  Sharing
    the engine between this scheduler and sync ``drain()`` callers is safe
    — both serialize in the executor and share its compile cache.
    ``stop()`` blocks: it flushes every queued request (all futures
    resolve) and joins the drain thread; schedulers are one-shot.

    ``params`` is bound at construction: the drain thread launches batches
    on its own schedule, so it must not depend on caller state at drain
    time.
    """

    def __init__(
        self,
        engine: BatchedSampler,
        params,
        policy: SchedulerPolicy | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.engine = engine
        self.params = params
        self.policy = policy or SchedulerPolicy()
        self._clock = clock
        self._cv = threading.Condition()
        # fuse queues keyed by the executor's group key (solver, seq, nfe):
        # only requests that may share a compiled bucket share a queue (seq
        # is the seq bucket under mixed-seq-len fusion, else exact seq_len;
        # nfe is the NFE bucket under mixed-NFE fusion, else exact nfe)
        self._queues: dict[
            tuple[str, int, int], deque[tuple[QueueItem, Future]]
        ] = {}
        self._next_ticket = 0
        self._thread: threading.Thread | None = None
        self._stopping = False
        # telemetry: running counters (a serving process launches batches
        # for its whole lifetime — no per-batch history is kept)
        self._batches = 0
        self._rows = 0
        # Prometheus-style instruments, registered into the shared executor
        # registry (get-or-create: front doors and sync drains scrape the
        # same /metrics)
        m = engine.executor.metrics
        self._m_depth = m.gauge(
            "sampler_queue_depth_rows",
            "pending request rows per fuse-group queue (solver, seq, nfe)",
        )
        self._m_submitted = m.counter(
            "sampler_requests_submitted_total", "requests admitted by submit()"
        )
        self._m_rejects = m.counter(
            "sampler_admission_rejects_total",
            "submits rejected by the max_queue_rows admission bound",
        )
        self._m_expired = m.counter(
            "sampler_deadline_expired_total",
            "queued requests failed fast past their deadline_ms",
        )
        self._m_latency = m.histogram(
            "sampler_request_latency_seconds",
            "arrival-to-result latency per delivered request",
        )

    # ---- client surface -------------------------------------------------
    def submit(self, req: SampleRequest) -> Future:
        """Enqueue from any thread; never blocks on execution (the drain
        thread runs batches).  The returned Future resolves to a
        :class:`~repro.serving.executor.SampleResult` (or raises, if the
        fused launch it rode in failed, or with
        :class:`DeadlineExceededError` if the request expired in queue);
        ``Future.result(timeout=...)`` is the blocking wait.  Invalid
        requests — unknown solver, per-solver (batch, nfe) constraints,
        seq_len above the engine's largest seq bucket, bad
        priority/deadline — raise here, at submit, so they can never
        poison a fused batch.  Raises :class:`QueueFullError` when the
        request's fuse-group queue is at the policy's admission bound, and
        RuntimeError after ``stop()``."""
        self.engine.executor.validate(req)
        fut: Future = Future()
        key = self.engine.executor.group_key(req)
        label = self._key_labels(key)
        with self._cv:
            if self._stopping:
                raise RuntimeError("scheduler is stopped")
            limit = self.policy.max_queue_rows
            if limit is not None:
                q = self._queues.get(key)
                rows = sum(item[1].batch for item, _ in q) if q else 0
                if rows + req.batch > limit:
                    self._m_rejects.inc(**label)
                    raise QueueFullError(
                        key, rows, limit, self.policy.retry_after_s()
                    )
            ticket = self._next_ticket
            self._next_ticket += 1
            item: QueueItem = (ticket, req, self._clock())
            self._queues.setdefault(key, deque()).append((item, fut))
            self._m_submitted.inc()
            self._set_depth_locked(key)
            self._cv.notify()
        return fut

    @staticmethod
    def _key_labels(key) -> dict:
        solver, seq, nfe = key
        return {"solver": solver, "seq": seq, "nfe": nfe}

    def _set_depth_locked(self, key) -> None:
        q = self._queues.get(key)
        rows = sum(item[1].batch for item, _ in q) if q else 0
        self._m_depth.set(rows, **self._key_labels(key))

    @property
    def pending(self) -> int:
        with self._cv:
            return sum(len(q) for q in self._queues.values())

    def stats(self) -> dict:
        with self._cv:
            batches, rows = self._batches, self._rows
            submitted = self._next_ticket
        return {
            K.SUBMITTED: submitted,
            K.BATCHES: batches,
            K.ROWS: rows,
            K.MEAN_BATCH_ROWS: (rows / batches) if batches else 0.0,
        }

    # ---- cold start ------------------------------------------------------
    def warmup(
        self,
        *,
        solvers: tuple[str, ...] | None = None,
        seq_lens: tuple[int, ...] | None = None,
        nfes: tuple[int, ...] | None = None,
        progress=None,
    ):
        """Ahead-of-time compile the engine's program grid with this
        scheduler's bound ``params`` — no sampling, no drains (see
        :meth:`FusedExecutor.warmup`).  Safe to run concurrently with live
        traffic (grid points a request compiled first are skipped); the
        front door runs this on a background thread at boot and gates
        ``/readyz`` on it."""
        return self.engine.warmup(
            self.params, solvers=solvers, seq_lens=seq_lens, nfes=nfes,
            progress=progress,
        )

    def warmup_status(self) -> dict:
        """Warmup progress of the underlying executor (what ``/readyz``
        reports)."""
        return self.engine.warmup_status()

    # ---- lifecycle (one-shot: stop() is final; build a new scheduler to
    # serve again) ---------------------------------------------------------
    def start(self) -> "AsyncBatchedSampler":
        with self._cv:
            if self._stopping:
                raise RuntimeError(
                    "scheduler is stopped — schedulers are one-shot, "
                    "construct a new AsyncBatchedSampler to serve again"
                )
            if self._thread is not None:
                raise RuntimeError("scheduler already started")
            self._thread = threading.Thread(
                target=self._loop, name="era-scheduler", daemon=True
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        """Clean shutdown: flush every queued request (their futures all
        resolve), then join the drain thread."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
            thread, self._thread = self._thread, None
        if thread is not None:
            thread.join()
        else:
            # never started: flush synchronously so no future is orphaned
            now = self._clock()
            with self._cv:
                expired = self._expire_locked(now)
                batches = self._pop_all()
            self._fail_expired(expired, now)
            self._run_batches(batches)

    def __enter__(self) -> "AsyncBatchedSampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- scheduling core (fake-clock testable, no thread required) ------
    def drain_once(self, now: float | None = None) -> int:
        """Fail every queued request past its deadline, then launch every
        queue the policy deems ready at ``now``; returns the number of
        fused batches launched.  This is the drain thread's step function,
        exposed for manual pumping and fake-clock tests."""
        with self._cv:
            t = self._clock() if now is None else now
            expired = self._expire_locked(t)
            batches = self._pop_ready(t)
        self._fail_expired(expired, t)
        return self._run_batches(batches)

    def _expire_locked(self, now: float):
        """Remove deadline-expired requests from every queue (fail-fast:
        they never occupy a fused batch).  Returns the removed entries for
        delivery outside the lock."""
        expired: list[tuple[QueueItem, Future]] = []
        for key, q in self._queues.items():
            if not q:
                continue
            keep = deque()
            for entry in q:
                (_, req, t_submit), _ = entry
                if (
                    req.deadline_ms is not None
                    and now - t_submit > req.deadline_ms / 1e3
                ):
                    expired.append(entry)
                else:
                    keep.append(entry)
            if len(keep) != len(q):
                self._queues[key] = keep
                self._set_depth_locked(key)
        return expired

    def _fail_expired(self, expired, now: float) -> None:
        for (_, req, t_submit), fut in expired:
            self._m_expired.inc()
            resolve_future(
                fut,
                exception=DeadlineExceededError(req, (now - t_submit) * 1e3),
            )

    def _pop_ready(self, now: float):
        """Pop ready chunks under the lock: highest-priority queue first
        (a queue's priority is its most urgent pending request's), oldest
        arrival breaking ties."""
        exe = self.engine.executor
        ready: list[tuple[int, float, tuple[str, int, int]]] = []
        for key, q in self._queues.items():
            if not q:
                continue
            rows = sum(item[1].batch for item, _ in q)
            oldest = q[0][0][2]
            if self.policy.should_launch(now, oldest, rows, exe.max_bucket):
                prio = max(item[1].priority for item, _ in q)
                ready.append((-prio, oldest, key))
        ready.sort()
        batches = []
        for _, _, key in ready:
            batches.extend(self._pop_chunks(key, full_queue=False))
        return batches

    def _pop_all(self):
        batches = []
        for key in list(self._queues):
            batches.extend(self._pop_chunks(key, full_queue=True))
        return batches

    def _pop_chunks(self, key, full_queue: bool):
        """Take rows from one queue: up to one largest bucket per launch,
        boarding higher-``priority`` requests first (FIFO among equal
        priorities — with no priorities set this is exactly arrival
        order); the remainder keeps its arrival times for the next launch.
        On flush the whole queue goes.  Non-fusable configs split into
        exact-size solo chunks."""
        exe = self.engine.executor
        entries = list(self._queues[key])
        order = sorted(
            range(len(entries)),
            key=lambda i: (-entries[i][0][1].priority, i),
        )
        taken_idx: list[int] = []
        total = 0
        for i in order:
            b = entries[i][0][1].batch
            if (
                not full_queue
                and taken_idx
                and exe.max_bucket
                and total + b > exe.max_bucket
            ):
                break
            taken_idx.append(i)
            total += b
        taken_set = set(taken_idx)
        # chunks assemble in boarding (priority) order; leftovers keep
        # their original arrival order and times
        taken = [entries[i] for i in taken_idx]
        self._queues[key] = deque(
            e for i, e in enumerate(entries) if i not in taken_set
        )
        self._set_depth_locked(key)
        futures = {item[0]: fut for item, fut in taken}
        return [
            (key, chunk, pad, futures)
            for chunk, pad in exe.pack([item for item, _ in taken])
        ]

    def _run_batches(self, batches) -> int:
        """Execute popped chunks outside the queue lock and resolve their
        futures; a failed launch fails only its own chunk's futures."""
        for (_solver, seq_len, nfe), chunk, pad, futures in batches:
            results: dict[int, SampleResult] = {}
            try:
                self.engine.executor.run_chunk(
                    self.params, seq_len, nfe, chunk, results, pad=pad
                )
            except Exception as e:  # noqa: BLE001 - delivered via futures
                for ticket, _, _ in chunk:
                    resolve_future(futures[ticket], exception=e)
                continue
            with self._cv:
                self._batches += 1
                self._rows += sum(req.batch for _, req, _ in chunk)
            for ticket, _, _ in chunk:
                self._m_latency.observe(results[ticket].latency_s)
                resolve_future(futures[ticket], results[ticket])
        return len(batches)

    def _next_deadline_s(self, now: float) -> float | None:
        """Seconds until the nearest wakeup: a queue's launch deadline or a
        request's expiry deadline, whichever comes first (None = nothing
        queued)."""
        deadlines = []
        for q in self._queues.values():
            if not q:
                continue
            deadlines.append(self.policy.deadline(q[0][0][2]))
            for (_, req, t_submit), _ in q:
                if req.deadline_ms is not None:
                    deadlines.append(t_submit + req.deadline_ms / 1e3)
        if not deadlines:
            return None
        return max(0.0, min(deadlines) - now)

    def _loop(self) -> None:
        while True:
            batches, expired, now = [], [], self._clock()
            with self._cv:
                while not self._stopping:
                    now = self._clock()
                    expired = self._expire_locked(now)
                    batches = self._pop_ready(now)
                    if batches or expired:
                        break
                    self._cv.wait(timeout=self._next_deadline_s(now))
                stopping = self._stopping
                if stopping:
                    now = self._clock()
                    expired.extend(self._expire_locked(now))
                    batches = self._pop_all()
            self._fail_expired(expired, now)
            self._run_batches(batches)
            if stopping:
                return
