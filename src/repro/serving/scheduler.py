"""Continuous-batching async scheduler for the diffusion sampling engine.

The sync :class:`~repro.serving.diffusion_sampler.BatchedSampler` only fuses
requests that happen to be pending at the same ``drain()`` call, so a steady
open-loop request stream degenerates to batch-of-1 drains and wastes the
fused step and mesh sharding.  :class:`AsyncBatchedSampler` fixes that with
the standard continuous-batching shape for fixed-cost (known-NFE) solvers:

* ``submit()`` is callable from any thread and returns a
  :class:`concurrent.futures.Future` that resolves to a
  :class:`~repro.serving.executor.SampleResult`;
* requests land in per-(solver, seq, nfe) queues — the executor's group
  key, where ``seq`` is the request's seq *bucket* when the engine does
  mixed-seq-len fusion and the exact ``seq_len`` otherwise.  Only
  same-group requests can fuse into one compiled bucket: a mixed ``era`` /
  ``ddim`` / ... stream batches per solver instead of cross-contaminating
  a bucket, while (under seq bucketing) requests of *different* lengths
  share a queue, a batch, and a compiled program;
* a background drain thread launches a queue when it reaches the policy's
  target bucket occupancy, or when its oldest request has waited
  ``max_wait_ms`` (deadline promotion — a lone request can never starve);
* ready queues are served oldest-request-first, FIFO within a queue, and
  each launch takes at most one largest-bucket's worth of rows (the rest
  keep their original arrival times for the next launch).

Execution goes through the same thread-safe
:class:`~repro.serving.executor.FusedExecutor` as the sync path, so the
compiled-bucket cache, mesh placement, and per-sample ERS isolation are
shared — a request's ``x0`` is bit-identical whether it runs via sync
``drain()``, via this scheduler under any arrival interleaving, or solo.

All policy decisions read an injectable ``clock`` and are reachable via
:meth:`AsyncBatchedSampler.drain_once`, so the scheduling logic is testable
with a fake clock and no background thread or real sleeps.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Callable

from repro.serving.diffusion_sampler import BatchedSampler
from repro.serving.executor import (
    QueueItem,
    SampleRequest,
    SampleResult,
    resolve_future,
)


def open_loop(gaps, emit, clock=time.perf_counter, sleep=time.sleep) -> float:
    """Drive an open-loop client: call ``emit(i)`` at each cumulative
    arrival offset of ``gaps``.  Sleeps only while ahead of schedule and
    catches up by emitting back-to-back when behind — a per-arrival sleep
    would floor the deliverable rate at the timer resolution.  When behind,
    ``sleep(0)`` still runs so a client colocated with the drain thread
    yields the interpreter instead of contending with it.  Returns the
    stream start time (same ``clock``), for makespan accounting.
    """
    t_start = clock()
    offset = 0.0
    for i, gap in enumerate(gaps):
        offset += gap
        delay = t_start + offset - clock()
        sleep(delay if delay > 0 else 0.0)
        emit(i)
    return t_start


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    """When does a queue of compatible requests launch as one fused batch?

    * ``max_wait_ms`` — upper bound on how long any request waits in the
      queue before its shape group is force-launched (deadline promotion).
      Lower = better p99 latency, higher = fuller batches / more throughput.
    * ``target_occupancy`` — fraction of the engine's largest batch bucket
      at which a queue launches immediately instead of waiting out the
      deadline.  1.0 waits for a completely full bucket; 0.25 launches as
      soon as a quarter-bucket of rows is pending.
    """

    max_wait_ms: float = 10.0
    target_occupancy: float = 1.0

    def target_rows(self, max_bucket: int | None) -> int | None:
        """Row count that triggers an immediate launch (None = deadline
        only, for engines with no batch buckets)."""
        if max_bucket is None:
            return None
        return max(1, math.ceil(self.target_occupancy * max_bucket))

    def deadline(self, oldest_t: float) -> float:
        return oldest_t + self.max_wait_ms / 1e3

    def should_launch(
        self, now: float, oldest_t: float, rows: int, max_bucket: int | None
    ) -> bool:
        target = self.target_rows(max_bucket)
        if target is not None and rows >= target:
            return True
        return now >= self.deadline(oldest_t)


class AsyncBatchedSampler:
    """Continuous-batching front end over a :class:`BatchedSampler`.

    ``submit()`` from any thread; a background drain thread (``start()`` /
    ``stop()``, or use as a context manager) fuses requests across arrival
    time through the engine's shared
    :class:`~repro.serving.executor.FusedExecutor`.

    Thread-safety and blocking behavior: ``submit`` / ``pending`` /
    ``stats`` are non-blocking and callable from any thread (results are
    delivered through futures); execution happens on the drain thread, or
    on the caller's thread for explicit ``drain_once()`` pumping.  Sharing
    the engine between this scheduler and sync ``drain()`` callers is safe
    — both serialize in the executor and share its compile cache.
    ``stop()`` blocks: it flushes every queued request (all futures
    resolve) and joins the drain thread; schedulers are one-shot.

    ``params`` is bound at construction: the drain thread launches batches
    on its own schedule, so it must not depend on caller state at drain
    time.
    """

    def __init__(
        self,
        engine: BatchedSampler,
        params,
        policy: SchedulerPolicy | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.engine = engine
        self.params = params
        self.policy = policy or SchedulerPolicy()
        self._clock = clock
        self._cv = threading.Condition()
        # fuse queues keyed by the executor's group key (solver, seq, nfe):
        # only requests that may share a compiled bucket share a queue (seq
        # is the seq bucket under mixed-seq-len fusion, else exact seq_len)
        self._queues: dict[
            tuple[str, int, int], deque[tuple[QueueItem, Future]]
        ] = {}
        self._next_ticket = 0
        self._thread: threading.Thread | None = None
        self._stopping = False
        # telemetry: running counters (a serving process launches batches
        # for its whole lifetime — no per-batch history is kept)
        self._batches = 0
        self._rows = 0

    # ---- client surface -------------------------------------------------
    def submit(self, req: SampleRequest) -> Future:
        """Enqueue from any thread; never blocks on execution (the drain
        thread runs batches).  The returned Future resolves to a
        :class:`~repro.serving.executor.SampleResult` (or raises, if the
        fused launch it rode in failed); ``Future.result(timeout=...)`` is
        the blocking wait.  Invalid requests — unknown solver, per-solver
        (batch, nfe) constraints, seq_len above the engine's largest seq
        bucket — raise here, at submit, so they can never poison a fused
        batch.  Raises RuntimeError after ``stop()``."""
        self.engine.executor.validate(req)
        fut: Future = Future()
        with self._cv:
            if self._stopping:
                raise RuntimeError("scheduler is stopped")
            ticket = self._next_ticket
            self._next_ticket += 1
            item: QueueItem = (ticket, req, self._clock())
            key = self.engine.executor.group_key(req)
            self._queues.setdefault(key, deque()).append((item, fut))
            self._cv.notify()
        return fut

    @property
    def pending(self) -> int:
        with self._cv:
            return sum(len(q) for q in self._queues.values())

    def stats(self) -> dict:
        with self._cv:
            batches, rows = self._batches, self._rows
            submitted = self._next_ticket
        return {
            "submitted": submitted,
            "batches": batches,
            "rows": rows,
            "mean_batch_rows": (rows / batches) if batches else 0.0,
        }

    # ---- lifecycle (one-shot: stop() is final; build a new scheduler to
    # serve again) ---------------------------------------------------------
    def start(self) -> "AsyncBatchedSampler":
        with self._cv:
            if self._stopping:
                raise RuntimeError(
                    "scheduler is stopped — schedulers are one-shot, "
                    "construct a new AsyncBatchedSampler to serve again"
                )
            if self._thread is not None:
                raise RuntimeError("scheduler already started")
            self._thread = threading.Thread(
                target=self._loop, name="era-scheduler", daemon=True
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        """Clean shutdown: flush every queued request (their futures all
        resolve), then join the drain thread."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
            thread, self._thread = self._thread, None
        if thread is not None:
            thread.join()
        else:
            # never started: flush synchronously so no future is orphaned
            with self._cv:
                batches = self._pop_all()
            self._run_batches(batches)

    def __enter__(self) -> "AsyncBatchedSampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- scheduling core (fake-clock testable, no thread required) ------
    def drain_once(self, now: float | None = None) -> int:
        """Launch every queue the policy deems ready at ``now``; returns the
        number of fused batches launched.  This is the drain thread's step
        function, exposed for manual pumping and fake-clock tests."""
        with self._cv:
            batches = self._pop_ready(self._clock() if now is None else now)
        return self._run_batches(batches)

    def _pop_ready(self, now: float):
        """Pop ready chunks under the lock, oldest-queue-first."""
        exe = self.engine.executor
        ready: list[tuple[float, tuple[str, int, int]]] = []
        for key, q in self._queues.items():
            if not q:
                continue
            rows = sum(item[1].batch for item, _ in q)
            oldest = q[0][0][2]
            if self.policy.should_launch(now, oldest, rows, exe.max_bucket):
                ready.append((oldest, key))
        ready.sort()  # deadline promotion: oldest arrival served first
        batches = []
        for _, key in ready:
            batches.extend(self._pop_chunks(key, full_queue=False))
        return batches

    def _pop_all(self):
        batches = []
        for key in list(self._queues):
            batches.extend(self._pop_chunks(key, full_queue=True))
        return batches

    def _pop_chunks(self, key, full_queue: bool):
        """Take rows from one queue: up to one largest bucket per launch
        (the remainder keeps its arrival times), or the whole queue on
        flush.  Non-fusable configs split into exact-size solo chunks."""
        exe = self.engine.executor
        q = self._queues[key]
        taken: list[tuple[QueueItem, Future]] = []
        total = 0
        while q:
            b = q[0][0][1].batch
            if (
                not full_queue
                and taken
                and exe.max_bucket
                and total + b > exe.max_bucket
            ):
                break
            entry = q.popleft()
            taken.append(entry)
            total += b
        futures = {item[0]: fut for item, fut in taken}
        return [
            (key, chunk, pad, futures)
            for chunk, pad in exe.pack([item for item, _ in taken])
        ]

    def _run_batches(self, batches) -> int:
        """Execute popped chunks outside the queue lock and resolve their
        futures; a failed launch fails only its own chunk's futures."""
        for (_solver, seq_len, nfe), chunk, pad, futures in batches:
            results: dict[int, SampleResult] = {}
            try:
                self.engine.executor.run_chunk(
                    self.params, seq_len, nfe, chunk, results, pad=pad
                )
            except Exception as e:  # noqa: BLE001 - delivered via futures
                for ticket, _, _ in chunk:
                    resolve_future(futures[ticket], exception=e)
                continue
            with self._cv:
                self._batches += 1
                self._rows += sum(req.batch for _, req, _ in chunk)
            for ticket, _, _ in chunk:
                resolve_future(futures[ticket], results[ticket])
        return len(batches)

    def _next_deadline_s(self, now: float) -> float | None:
        """Seconds until the nearest queue deadline (None = nothing queued)."""
        deadlines = [
            self.policy.deadline(q[0][0][2])
            for q in self._queues.values()
            if q
        ]
        if not deadlines:
            return None
        return max(0.0, min(deadlines) - now)

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._stopping:
                    now = self._clock()
                    batches = self._pop_ready(now)
                    if batches:
                        break
                    self._cv.wait(timeout=self._next_deadline_s(now))
                stopping = self._stopping
                if stopping:
                    batches = self._pop_all()
            self._run_batches(batches)
            if stopping:
                return
