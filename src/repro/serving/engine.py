"""Autoregressive serving engine: batched prefill + decode over a KV cache.

The engine owns the jitted ``prefill_step`` / ``decode_step`` closures — the
exact functions the multi-pod dry-run lowers for the ``prefill_32k`` /
``decode_32k`` / ``long_500k`` input shapes — plus a simple synchronous
batcher for the runnable examples.

Long-context policy (DESIGN.md): decode caches size ``min(max_len, window)``
slots; for dense architectures the ``long_500k`` shape runs the
sliding-window variant (ring-buffer cache, `window_override`), for
SSM/hybrid the state is O(1) and the attention cache (if any) is the SWA
ring.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.configs.registry import long_context_policy
from repro.models.model import Model
from repro.parallel.sharding import ParamReplicator, ShardingRules

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 4096              # max absolute positions served
    window_override: int = -1        # -1: arch default; >0 force SWA window
    greedy: bool = True
    temperature: float = 1.0


def cache_slots(cfg: ModelConfig, serve: ServeConfig) -> int:
    """How many KV slots the decode cache needs."""
    window = serve.window_override
    if window < 0:
        window = cfg.sliding_window
    if window and window > 0:
        return min(serve.max_len, window + cfg.num_meta_tokens)
    return serve.max_len


def resolve_window(cfg: ModelConfig, serve: ServeConfig, seq_len: int) -> int:
    """Window to run decode with (0 = full attention)."""
    if serve.window_override >= 0:
        return serve.window_override
    if long_context_policy(cfg) == "swa" and seq_len > 65536:
        return cfg.long_context_window
    return -1  # per-block default


class Engine:
    """Synchronous batched serving around a Model.

    With ``mesh=`` set, params replicate over the mesh and each request
    batch shards across the data axes when its size divides the
    data-parallel size (the prefill cache inherits that placement, so
    decode stays data-parallel for the whole generation)."""

    def __init__(
        self,
        model: Model,
        serve: ServeConfig = ServeConfig(),
        mesh: Mesh | None = None,
    ):
        self.model = model
        self.serve = serve
        self.cfg = model.config
        self.mesh = mesh
        self._replicate = ParamReplicator(mesh) if mesh is not None else None
        self._rules = ShardingRules(self.cfg, mesh) if mesh is not None else None
        slots = cache_slots(self.cfg, serve)
        self._prefill = jax.jit(
            lambda p, b, w: model.prefill(p, b, slots, w),
            static_argnums=(2,),
        )
        self._decode = jax.jit(
            lambda p, c, b, w: model.decode(p, c, b, w),
            static_argnums=(3,),
            donate_argnums=(1,),
        )

    # ---- mesh placement ----
    def _place(self, params, batch: dict):
        """Replicate params, batch-shard the request over the data axes
        (per-leaf: a leading dim that doesn't divide dp replicates)."""
        if self.mesh is None:
            return params, batch
        params = self._replicate(params)
        batch = jax.tree.map(jnp.asarray, batch)
        batch = jax.tree.map(
            jax.device_put, batch, self._rules.batch_sharding(batch)
        )
        return params, batch

    # ---- steps (also used by the dry-run) ----
    def prefill_step(self, params, batch: dict, window_override: int = -1):
        return self._prefill(params, batch, window_override)

    def decode_step(self, params, cache, batch: dict, window_override: int = -1):
        return self._decode(params, cache, batch, window_override)

    # ---- sampling ----
    def _sample_token(self, logits: Array, key: jax.Array) -> Array:
        logits = logits[:, -1, : self.cfg.vocab_size].astype(jnp.float32)
        if self.serve.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.serve.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(
        self,
        params,
        prompts: Array,          # (B, S_prompt) int32
        max_new_tokens: int,
        extras: dict | None = None,
        key: jax.Array | None = None,
    ) -> Array:
        """Prefill the prompts, then decode greedily/sampled."""
        key = jax.random.PRNGKey(0) if key is None else key
        batch = {"tokens": prompts, **(extras or {})}
        params, batch = self._place(params, batch)
        wo = resolve_window(self.cfg, self.serve, prompts.shape[1] + max_new_tokens)
        logits, cache = self.prefill_step(params, batch, wo)
        off = self.cfg.num_meta_tokens
        if self.cfg.family == "vlm" and extras:
            off += extras["patches"].shape[1]
        pos = off + prompts.shape[1]

        toks = []
        key, sub = jax.random.split(key)
        nxt = self._sample_token(logits, sub)
        toks.append(nxt)
        for i in range(max_new_tokens - 1):
            dec = {"tokens": nxt[:, None], "pos": jnp.int32(pos + i)}
            logits, cache = self.decode_step(params, cache, dec, wo)
            key, sub = jax.random.split(key)
            nxt = self._sample_token(logits, sub)
            toks.append(nxt)
        return jnp.stack(toks, axis=1)
