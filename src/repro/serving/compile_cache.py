"""Persistent XLA compilation cache wiring + disk-hit accounting.

Two small, process-global facilities behind the executor's AOT compile
boundary:

* :func:`configure_persistent_cache` points ``jax.config`` at an on-disk
  compilation cache (``jax_compilation_cache_dir``) so a redeployed
  replica's warmup re-loads yesterday's executables from disk instead of
  paying fresh XLA compiles.  JAX's own defaults only persist compiles
  slower than 1s — far above the small serving shapes here — so the
  engine defaults both persistence thresholds to "persist everything".
* :func:`disk_cache_hits` counts compiles that were served from that
  cache, via JAX's ``jax.monitoring`` event stream.  The executor
  snapshots this counter across each ``lower().compile()`` call to label
  the compile ``source="disk"`` vs ``"fresh"`` — XLA offers no per-call
  return channel for "this came from the cache".

Both are process-global because the underlying state is: ``jax.config``
flags and the monitoring listener registry apply to every compile in the
process, not to one engine instance.
"""

from __future__ import annotations

import threading

import jax
from jax import monitoring
from jax._src import compilation_cache as _jax_compilation_cache

#: monitoring event XLA's compiler records on a persistent-cache read hit
CACHE_HIT_EVENT = "/jax/compilation_cache/cache_hits"

_lock = threading.Lock()
_disk_hits = 0
_listening = False


def _on_event(event: str, **kwargs) -> None:
    global _disk_hits
    if event == CACHE_HIT_EVENT:
        with _lock:
            _disk_hits += 1


def _ensure_listener() -> None:
    # register exactly once per process; the listener registry has no
    # dedup, so a double registration would double-count every hit
    global _listening
    with _lock:
        if _listening:
            return
        _listening = True
    monitoring.register_event_listener(_on_event)


def disk_cache_hits() -> int:
    """Process-wide count of XLA compiles served from the persistent
    compilation cache (always 0 when no cache dir is configured).

    First call registers the monitoring listener, so take a baseline
    reading *before* the compile being classified.
    """
    _ensure_listener()
    with _lock:
        return _disk_hits


def configure_persistent_cache(
    cache_dir: str,
    *,
    min_entry_size_bytes: int = -1,
    min_compile_time_secs: float = 0.0,
) -> None:
    """Enable the on-disk XLA compilation cache at ``cache_dir``.

    The dir is created on first write and is safe to share across
    processes and boots — that sharing is the point: entries are keyed by
    the lowered computation + compile options + jax/XLA versions, so the
    second boot of an identical engine turns every warmup compile into a
    disk hit.

    ``min_entry_size_bytes`` / ``min_compile_time_secs`` mirror the
    ``jax_persistent_cache_*`` flags but default to persisting everything
    (-1 / 0.0): serving-bucket programs at ~10 NFE can compile in well
    under JAX's 1s default threshold, which would silently persist
    nothing.

    Safe to call after compiles have already run: JAX latches its cache
    handle at the first compile of the process (``_initialize_cache`` is
    once-only), so this resets that latch to pick up the new dir.
    """
    _ensure_listener()  # count disk hits from the very first compile on
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_enable_compilation_cache", True)
    jax.config.update(
        "jax_persistent_cache_min_entry_size_bytes", int(min_entry_size_bytes)
    )
    jax.config.update(
        "jax_persistent_cache_min_compile_time_secs", float(min_compile_time_secs)
    )
    # un-latch jax's once-per-process cache init: if any compile ran before
    # this call (engine build, bench baseline, test setup), the cache handle
    # was initialized to "no dir" and every later compile would silently
    # skip the disk
    _jax_compilation_cache.reset_cache()
