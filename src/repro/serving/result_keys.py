"""The serving stack's documented result/telemetry dictionary keys.

Every stringly-typed key that crosses a serving API boundary lives here,
once:

* **info keys** — what :attr:`SampleResult.info` (and therefore
  ``SamplerService.sample(...).info``) carries alongside ``x0``;
* **aux keys** — the solver diagnostics merged into ``info`` (produced by
  the solver programs in ``core/``, scoped per request by the executor);
* **stats keys** — ``AsyncBatchedSampler.stats()`` counters.

serving/ and benchmarks/ must reference these constants instead of
re-typing the literals — ``tests/test_result_keys.py`` greps both trees
and fails on any stringly-typed duplicate, so a renamed key can never
silently fork into two spellings.  The wire schema
(``serving/frontdoor.py``) serializes ``SampleResult`` field-by-field, so
these keys are also exactly what a front-door client sees in a response's
``aux``/``info``.
"""

from __future__ import annotations

# ---- SampleResult.info keys (facade info dict / wire response) ----------
#: wall time of the fused batch the request rode in (shared by batch-mates)
WALL_S = "wall_s"
#: submit -> result wall time for this request alone
LATENCY_S = "latency_s"
#: batch size the compiled program ran at (batch bucket, or exact size)
PADDED_BATCH = "padded_batch"
#: sequence length the compiled program ran at (seq bucket under seq
#: bucketing, exact ``seq_len`` otherwise)
PADDED_SEQ_LEN = "padded_seq_len"
#: NFE budget the compiled program scanned to (NFE bucket under nfe
#: bucketing, exact ``nfe`` otherwise) — the request's own steps beyond its
#: exact NFE are inert pad steps under the per-row step mask
PADDED_NFE = "padded_nfe"

#: the engine-telemetry keys every ``SampleResult.info`` carries, in order
INFO_KEYS = (WALL_S, LATENCY_S, PADDED_BATCH, PADDED_SEQ_LEN, PADDED_NFE)

# ---- solver-diagnostic aux keys (merged into info, scoped per request) --
#: per-step ERS error measure (batch mean under per-sample ERS), shape (nfe,)
DELTA_EPS_HISTORY = "delta_eps_history"
#: per-step, per-row ERS error measure under per-sample ERS, shape (nfe, B)
DELTA_EPS_HISTORY_PER_SAMPLE = "delta_eps_history_per_sample"
#: per-step Lagrange basis selections under per-sample ERS, shape (nfe, B, k)
ERS_SELECTION_HISTORY = "ers_selection_history"
#: full latent trajectory when ``return_trajectory`` is set
TRAJECTORY = "trajectory"
#: per-row model evaluations actually spent by the adaptive DPM-Solver
#: (accept + reject), shape (B,) int32 — contrast with the nfe *budget*
REALIZED_NFE = "realized_nfe"

#: the documented solver-diagnostic keys, in order
AUX_KEYS = (
    DELTA_EPS_HISTORY,
    DELTA_EPS_HISTORY_PER_SAMPLE,
    ERS_SELECTION_HISTORY,
    TRAJECTORY,
    REALIZED_NFE,
)

# ---- AsyncBatchedSampler.stats() keys -----------------------------------
#: total requests accepted by submit()
SUBMITTED = "submitted"
#: fused batches launched
BATCHES = "batches"
#: rows executed across all launched batches
ROWS = "rows"
#: mean rows per fused batch (fuse efficiency)
MEAN_BATCH_ROWS = "mean_batch_rows"

STATS_KEYS = (SUBMITTED, BATCHES, ROWS, MEAN_BATCH_ROWS)
