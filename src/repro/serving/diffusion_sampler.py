"""Diffusion sampling service: ERA-Solver (or any registered solver) driving
a DiffusionLM denoiser — the paper's deployment shape, grown into a
request-batching engine.

Architecture:

* :class:`~repro.serving.executor.FusedExecutor` — the thread-safe
  execution core: one jitted XLA program per (sample-shape, nfe, k) bucket
  (``jax.lax.scan`` over NFE steps inside; eps/t Lagrange buffers donated on
  accelerator backends), mesh placement, chunk packing, and per-request aux
  scoping.  The jit cache is keyed by bucket, so a steady request stream
  compiles exactly once per bucket no matter how batch sizes fluctuate.
* :class:`BatchedSampler` — the sync engine.  ``submit()`` enqueues requests
  (from any thread) and returns a ticket whose :class:`~concurrent.futures.
  Future` resolves at drain time; ``drain()`` groups pending requests by
  (solver, seq_len, nfe), pads each group's batch up to a shape bucket, and
  runs each chunk through the shared executor.
* **Per-request solver routing** — ``SampleRequest.solver`` names any
  registry solver (``era``, ``ddim``, ``dpm_solver_pp2m``, ...); the
  executor routes each request to that solver's
  :class:`~repro.core.SolverProgram` (None = the engine's default solver).
  Every program gets the same engine treatment ERA does: a single-scan
  compile per bucket, donated history buffers, mesh-sharded carries, and
  per-request aux scoping — there is no solver-specific code in serving/.
* :class:`~repro.serving.scheduler.AsyncBatchedSampler` — the
  continuous-batching front end over the same executor: a background drain
  thread batches requests across arrival time under a
  :class:`~repro.serving.scheduler.SchedulerPolicy`.
* Per-request isolation inside a fused batch comes from per-sample ERS
  (``ERAConfig.per_sample=True``, the engine default for ERA): every sample
  row measures its own delta_eps and selects its own Lagrange bases, so a
  batch-of-N run is equivalent to N independent runs.  Configs with the
  paper's shared scalar delta_eps couple the batch, so the engine serves
  them one exact-size request at a time instead of fusing (and, on a mesh,
  only at dp-multiple batches — exact-size runs cannot round up).
* The fused Pallas step is the default path; core gates it with a one-time
  per-backend numerics parity probe (``era._fused_ops`` /
  ``kernels.ops.fused_step_parity``) and falls back to the pure-jnp combine
  if the kernel misbehaves — ``fused_path_ok()`` reports the outcome.
* Mesh mode (``mesh=`` a ``jax.sharding.Mesh``): the engine batch-shards the
  latents and Lagrange eps buffer over the mesh's data axes
  (``parallel.sharding.sampler_shardings``) and replicates the denoiser
  params, so one fused drain spreads its rows across every device.  Batch
  buckets round up to multiples of the data-parallel size (no ragged
  shards), and per-sample ERS keeps each row's error measurement and base
  selection local to its shard — the solver loop runs collective-free.
* :class:`SamplerService` — the original one-call facade, now a thin
  future-consuming client over the engine with exact-size buckets.
"""

from __future__ import annotations

import threading
import time
import warnings
from concurrent.futures import Future

import jax
from jax.sharding import Mesh

from repro.core import NoiseSchedule, SolverConfig, get_program
from repro.core import era as era_mod
from repro.models.diffusion import DiffusionLM
from repro.serving.executor import (
    DEFAULT_MAX_BATCH,
    DEFAULT_MAX_NFE,
    DEFAULT_MAX_SEQ_LEN,
    FusedExecutor,
    QueueItem,
    SampleRequest,
    SampleResult,
    resolve_future,
)
from repro.serving.metrics import MetricsRegistry

Array = jax.Array

def fused_path_ok() -> bool:
    """Is the fused Pallas step active on this backend?  (The parity gate
    itself lives in core — `era._fused_ops` — so every ERA entry point is
    covered; this is the serving-side introspection hook.)"""
    return era_mod._fused_ops() is not None


class BatchedSampler:
    """Request-batching diffusion sampling engine (submit/drain).

    Thread-safety: ``submit`` / ``submit_with_future`` / ``future`` /
    ``pending`` may be called from any thread; concurrent ``drain()``
    callers are safe (each drains whatever is pending when it takes the
    queue, and chunk execution serializes inside the shared executor).
    ``drain()`` blocks until every chunk it took has finished on device.

    ``seq_buckets`` opts into mixed-seq-len fusion (see
    :class:`~repro.serving.executor.FusedExecutor`): requests whose
    ``seq_len`` differs fuse into one compiled batch, right-padded and
    length-masked, with exact-shape fallback when masking is unsupported.

    ``nfe_buckets`` opts into mixed-NFE fusion the same way: requests
    whose ``nfe`` differs fuse into one compiled batch that scans to the
    bucketed max step count, with per-row step masks freezing each row
    bitwise once its own budget is spent; solvers without a step-masked
    scan fall back to exact-NFE grouping.
    """

    def __init__(
        self,
        dlm: DiffusionLM,
        schedule: NoiseSchedule,
        solver: str = "era",
        solver_config: SolverConfig | None = None,
        batch_buckets: tuple[int, ...] | None = (1, 8, 64),
        mesh: Mesh | None = None,
        seq_buckets: tuple[int, ...] | None = None,
        nfe_buckets: tuple[int, ...] | None = None,
        metrics: MetricsRegistry | None = None,
        max_batch: int | None = DEFAULT_MAX_BATCH,
        max_nfe: int | None = DEFAULT_MAX_NFE,
        max_seq_len: int | None = DEFAULT_MAX_SEQ_LEN,
    ):
        self.executor = FusedExecutor(
            dlm, schedule, solver, solver_config, batch_buckets, mesh,
            seq_buckets=seq_buckets, nfe_buckets=nfe_buckets,
            metrics=metrics,
            max_batch=max_batch, max_nfe=max_nfe, max_seq_len=max_seq_len,
        )
        self._queue_lock = threading.Lock()
        self._pending: list[QueueItem] = []
        self._futures: dict[int, Future] = {}
        self._next_ticket = 0

    # engine surface mirrored from the executor (tests/benchmarks read these)
    @property
    def dlm(self) -> DiffusionLM:
        return self.executor.dlm

    @property
    def schedule(self) -> NoiseSchedule:
        return self.executor.schedule

    @property
    def solver_name(self) -> str:
        return self.executor.solver_name

    @property
    def solver_config(self) -> SolverConfig:
        return self.executor.solver_config

    @property
    def mesh(self) -> Mesh | None:
        return self.executor.mesh

    @property
    def dp(self) -> int:
        return self.executor.dp

    @property
    def batch_buckets(self) -> tuple[int, ...] | None:
        return self.executor.batch_buckets

    @property
    def seq_buckets(self) -> tuple[int, ...] | None:
        return self.executor.seq_buckets

    @property
    def nfe_buckets(self) -> tuple[int, ...] | None:
        return self.executor.nfe_buckets

    @property
    def metrics(self) -> MetricsRegistry:
        return self.executor.metrics

    # ---- request queue -------------------------------------------------
    def submit(self, req: SampleRequest) -> int:
        """Deprecated: enqueue a request and return its int ticket for the
        drain() result map.

        The int-ticket surface predates futures and cannot express
        off-thread waiting safely (with concurrent drains, the window
        between ``submit()`` and ``future()`` is wide enough for delivery
        to pop the Future first) — use :meth:`submit_with_future`, whose
        Future is also what the scheduler and the front door deliver
        through.  Thread-safe; invalid requests are rejected here, not at
        drain time.
        """
        warnings.warn(
            "BatchedSampler.submit (int tickets) is deprecated; use "
            "submit_with_future() and wait on the returned Future",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.submit_with_future(req)[0]

    def submit_with_future(self, req: SampleRequest) -> tuple[int, Future]:
        """Atomically enqueue a request and hand back its delivery Future —
        no concurrent ``drain()`` can resolve-and-pop the ticket in
        between."""
        self.executor.validate(req)
        with self._queue_lock:
            ticket = self._next_ticket
            self._next_ticket += 1
            self._pending.append((ticket, req, time.perf_counter()))
            fut = self._futures[ticket] = Future()
        return ticket, fut

    def future(self, ticket: int) -> Future:
        """The Future that ``drain()`` resolves for this ticket.

        Grab it between ``submit()`` and the drain: delivery pops the
        Future (the engine does not pin results), so asking for an
        already-delivered ticket is an error, not a silent re-wait.
        """
        with self._queue_lock:
            if ticket not in self._futures:
                raise KeyError(
                    f"ticket {ticket} has no outstanding future — its result "
                    "was already delivered by drain(); call future() before "
                    "the drain that serves the ticket"
                )
            return self._futures[ticket]

    @property
    def pending(self) -> int:
        with self._queue_lock:
            return len(self._pending)

    def drain(self, params) -> dict[int, SampleResult]:
        """Run all pending requests, fused per (solver, seq, nfe) group
        (seq = seq bucket under mixed-seq-len fusion, exact seq_len
        otherwise).

        Also resolves each drained ticket's Future, so a drain from one
        thread delivers results to submitters waiting on other threads.
        A chunk that fails fails only its own tickets: their Futures get
        the exception (no waiter hangs), every other chunk still runs and
        delivers, and the first failure re-raises at the end for the
        drain() caller.
        """
        with self._queue_lock:
            pending, self._pending = self._pending, []
        # only same-group-key requests can fuse into one compiled bucket:
        # (solver, seq, nfe), where seq is the seq *bucket* when the engine
        # does mixed-seq-len fusion and the exact seq_len otherwise —
        # mixed-solver traffic batches per solver either way
        groups: dict[tuple[str, int, int], list[QueueItem]] = {}
        for item in pending:
            _, req, _ = item
            groups.setdefault(self.executor.group_key(req), []).append(item)

        results: dict[int, SampleResult] = {}
        failure: Exception | None = None
        for (_solver, seq_len, nfe), items in groups.items():
            for chunk, pad in self.executor.pack(items):
                try:
                    self.executor.run_chunk(
                        params, seq_len, nfe, chunk, results, pad=pad
                    )
                except Exception as e:  # noqa: BLE001 - delivered via futures
                    if failure is None:
                        failure = e
                    with self._queue_lock:
                        futs = [
                            self._futures.pop(t) for t, _, _ in chunk
                        ]
                    for fut in futs:
                        resolve_future(fut, exception=e)
        with self._queue_lock:
            futures = {t: self._futures.pop(t) for t in results}
        for ticket, fut in futures.items():
            resolve_future(fut, results[ticket])
        if failure is not None:
            raise failure
        return results

    # ---- cold start -----------------------------------------------------
    def warmup(
        self,
        params,
        *,
        solvers: tuple[str, ...] | None = None,
        seq_lens: tuple[int, ...] | None = None,
        nfes: tuple[int, ...] | None = None,
        progress=None,
    ):
        """Ahead-of-time compile the configured (solver × batch-bucket ×
        seq-bucket × nfe) program grid — no sampling, no drains; see
        :meth:`FusedExecutor.warmup`.  After this returns, the first real
        request of any warmed shape runs the solver, not the compiler.
        Returns the warmup report dict."""
        return self.executor.warmup(
            params, solvers=solvers, seq_lens=seq_lens, nfes=nfes,
            progress=progress,
        )

    def warmup_status(self):
        """Warmup progress snapshot (``/readyz`` payload material)."""
        return self.executor.warmup_status()

    # ---- introspection (tests / benchmarks) ----------------------------
    def compile_cache(self):
        """Bucket-key -> compiled executable map (each program is lowered
        and compiled exactly once, by warmup or by its first chunk)."""
        return self.executor.compile_cache()

    def compile_stats(self):
        """Program-acquisition counts by source: fresh / disk / memory."""
        return self.executor.compile_stats()


class SamplerService:
    """One-call facade over :class:`BatchedSampler` (exact-size buckets).

    ``sample()`` is synchronous and blocking: it submits, drains, and
    returns the finished :class:`~repro.serving.executor.SampleResult` —
    the same type every other entry point delivers.  ``result.x0`` is the
    latents; ``result.info`` flattens the engine telemetry
    (:data:`~repro.serving.result_keys.INFO_KEYS`: ``wall_s`` /
    ``latency_s`` / ``padded_batch`` / ``padded_seq_len``) together with
    every solver diagnostic from ``result.aux`` (``delta_eps_history``,
    ``ers_selection_history``, ...), scoped to this request.  The
    pre-unification ``x0, info = svc.sample(...)`` tuple unpacking still
    works as a deprecation shim.

    It is thread-safe (the underlying engine is), but callers wanting
    concurrency should use :class:`BatchedSampler` or the async scheduler
    directly — the facade runs one exact-size batch per call and never
    fuses strangers.

    ``engine=`` injects a pre-built :class:`BatchedSampler` (e.g. from
    :func:`repro.serving.factory.build_engine`) instead of constructing a
    private exact-size one — the facade then inherits that engine's
    buckets, mesh, and metrics registry.
    """

    def __init__(
        self,
        dlm: DiffusionLM | None = None,
        schedule: NoiseSchedule | None = None,
        solver: str = "era",
        solver_config: SolverConfig | None = None,
        mesh: Mesh | None = None,
        engine: BatchedSampler | None = None,
    ):
        if engine is None:
            if dlm is None or schedule is None:
                raise ValueError(
                    "SamplerService needs (dlm, schedule) or a pre-built "
                    "engine="
                )
            if solver_config is None:
                # the facade defaults to the paper config (shared-delta
                # ERA), not the engine's fusable serving default — it runs
                # exact-size
                solver_config = get_program(solver).default_config()
            engine = BatchedSampler(
                dlm, schedule, solver, solver_config,
                batch_buckets=None, mesh=mesh,
            )
        self._engine = engine
        self.dlm = engine.dlm
        self.schedule = engine.schedule
        self.solver_name = engine.solver_name
        self.solver_config = engine.solver_config

    def sample(self, params, req: SampleRequest) -> SampleResult:
        """Generate ``req.batch`` sequences of latents via the solver;
        blocking.  Returns the request's :class:`SampleResult`."""
        _, fut = self._engine.submit_with_future(req)
        self._engine.drain(params)
        return fut.result()

    # ---- dry-run hook: the full solver loop as one lowerable program ----
    def sample_program(self):
        sample_fn = get_program(self.solver_name).sample
        cfg = self.solver_config

        def program(params, x_init):
            return sample_fn(
                self.dlm.eps_fn(params), x_init, self.schedule, cfg
            ).x0

        return program
