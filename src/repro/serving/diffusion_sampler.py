"""Diffusion sampling service: ERA-Solver (or any registered solver) driving
a DiffusionLM denoiser — the paper's deployment shape, grown into a
request-batching engine.

Architecture:

* :class:`BatchedSampler` — the engine.  ``submit()`` enqueues requests;
  ``drain()`` groups them by (seq_len, nfe), pads each group's batch up to a
  shape bucket, and runs the whole solver loop as ONE jitted XLA program per
  bucket (``jax.lax.scan`` over NFE steps inside; eps/t Lagrange buffers
  donated on accelerator backends).  The jit cache is keyed by bucket, so a
  steady request stream compiles exactly once per (sample-shape, nfe, k)
  bucket no matter how request batch sizes fluctuate.
* Per-request isolation inside a fused batch comes from per-sample ERS
  (``ERAConfig.per_sample=True``, the engine default for ERA): every sample
  row measures its own delta_eps and selects its own Lagrange bases, so a
  batch-of-N run is equivalent to N independent runs.  Configs with the
  paper's shared scalar delta_eps couple the batch, so the engine serves
  them one exact-size request at a time instead of fusing.
* The fused Pallas step is the default path; core gates it with a one-time
  per-backend numerics parity probe (``era._fused_ops`` /
  ``kernels.ops.fused_step_parity``) and falls back to the pure-jnp combine
  if the kernel misbehaves — ``fused_path_ok()`` reports the outcome.
* Mesh mode (``mesh=`` a ``jax.sharding.Mesh``): the engine batch-shards the
  latents and Lagrange eps buffer over the mesh's data axes
  (``parallel.sharding.sampler_shardings``) and replicates the denoiser
  params, so one fused drain spreads its rows across every device.  Batch
  buckets round up to multiples of the data-parallel size (no ragged
  shards), and per-sample ERS keeps each row's error measurement and base
  selection local to its shard — the solver loop runs collective-free.
* :class:`SamplerService` — the original one-call facade, now a thin wrapper
  over the engine with exact-size buckets (no padding).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import ERAConfig, NoiseSchedule, SolverConfig, get_solver
from repro.core import era as era_mod
from repro.models.diffusion import DiffusionLM
from repro.parallel.sharding import (
    ParamReplicator,
    dp_size,
    round_to_dp,
    sampler_shardings,
)

Array = jax.Array

def fused_path_ok() -> bool:
    """Is the fused Pallas step active on this backend?  (The parity gate
    itself lives in core — `era._fused_ops` — so every ERA entry point is
    covered; this is the serving-side introspection hook.)"""
    return era_mod._fused_ops() is not None


@dataclasses.dataclass(frozen=True)
class SampleRequest:
    batch: int
    seq_len: int
    nfe: int = 10
    solver: str = "era"
    seed: int = 0


@dataclasses.dataclass
class SampleResult:
    """Per-request output of a drained batch."""

    x0: Array                # (batch, seq_len, d_model)
    aux: dict[str, Any]      # solver diagnostics, scoped to this request's
                             # rows (per-sample histories / trajectories
                             # exclude batch-mates and pad rows)
    latency_s: float         # submit -> result wall time
    batch_wall_s: float      # wall time of the fused batch this rode in
    padded_batch: int        # bucket size the batch ran at


class BatchedSampler:
    """Request-batching diffusion sampling engine (submit/drain)."""

    def __init__(
        self,
        dlm: DiffusionLM,
        schedule: NoiseSchedule,
        solver: str = "era",
        solver_config: SolverConfig | None = None,
        batch_buckets: tuple[int, ...] | None = (1, 8, 64),
        mesh: Mesh | None = None,
    ):
        self.dlm = dlm
        self.schedule = schedule
        self.solver_name = solver
        if solver_config is None:
            # per-sample ERS isolates co-batched requests from each other
            solver_config = (
                ERAConfig(per_sample=True) if solver == "era" else SolverConfig()
            )
        self.solver_config = solver_config
        self.mesh = mesh
        self.dp = dp_size(mesh) if mesh is not None else 1
        if batch_buckets:
            # every fused batch must split evenly over the data axes, so
            # buckets round up to dp multiples (1/8/64 on dp=8 -> 8/64)
            batch_buckets = sorted({round_to_dp(b, mesh) for b in batch_buckets})
        self.batch_buckets = tuple(batch_buckets) if batch_buckets else None
        self._jitted: dict[Any, Any] = {}
        self._shardings_cache: dict[Any, Any] = {}
        self._replicate = ParamReplicator(mesh) if mesh is not None else None
        self._pending: list[tuple[int, SampleRequest, float]] = []
        self._next_ticket = 0

    # ---- request queue -------------------------------------------------
    def submit(self, req: SampleRequest) -> int:
        """Enqueue a request; returns its ticket for the drain() result map.

        Invalid requests are rejected here, not at drain time — a bad
        request must not poison the queue for its co-batched neighbours.
        """
        if req.batch < 1:
            raise ValueError(f"batch must be >= 1, got {req.batch}")
        k = getattr(self.solver_config, "k", None)
        if k is not None and req.nfe < k:
            raise ValueError(
                f"ERA-Solver needs nfe >= k ({req.nfe} < {k}); "
                "lower k in the engine's solver_config or raise nfe"
            )
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, req, time.perf_counter()))
        return ticket

    @property
    def pending(self) -> int:
        return len(self._pending)

    def drain(self, params) -> dict[int, SampleResult]:
        """Run all pending requests, fused per (seq_len, nfe) shape bucket."""
        groups: dict[tuple[int, int], list[tuple[int, SampleRequest, float]]] = {}
        for item in self._pending:
            _, req, _ = item
            groups.setdefault((req.seq_len, req.nfe), []).append(item)
        self._pending = []

        results: dict[int, SampleResult] = {}
        max_bucket = self.batch_buckets[-1] if self.batch_buckets else None
        # ERA with a shared (non-per-sample) delta_eps couples every batch
        # row through one global error norm — fusing strangers or adding pad
        # rows would change each request's result, so such configs are
        # served one exact-size request at a time instead
        fusable = (
            not isinstance(self.solver_config, ERAConfig)
            or self.solver_config.per_sample
        )
        for (seq_len, nfe), items in groups.items():
            if not fusable:
                for item in items:
                    self._run_chunk(
                        params, seq_len, nfe, [item], results, pad=False
                    )
                continue
            chunk: list[tuple[int, SampleRequest, float]] = []
            total = 0
            for item in items:
                b = item[1].batch
                if chunk and max_bucket and total + b > max_bucket:
                    self._run_chunk(params, seq_len, nfe, chunk, results)
                    chunk, total = [], 0
                chunk.append(item)
                total += b
            if chunk:
                self._run_chunk(params, seq_len, nfe, chunk, results)
        return results

    # ---- fused execution -----------------------------------------------
    def _bucket_batch(self, n: int) -> int:
        if not self.batch_buckets:
            return round_to_dp(n, self.mesh)
        for b in self.batch_buckets:
            if n <= b:
                return b
        # oversize request: exact-size compile (dp-rounded on a mesh)
        return round_to_dp(n, self.mesh)

    # ---- mesh placement ------------------------------------------------
    def _shardings(self, batch: int):
        """Carry shardings for a padded batch (None off-mesh)."""
        if self.mesh is None:
            return None
        key = batch
        if key not in self._shardings_cache:
            per_sample = (
                isinstance(self.solver_config, ERAConfig)
                and self.solver_config.per_sample
            )
            self._shardings_cache[key] = sampler_shardings(
                self.mesh, batch=batch, per_sample=per_sample
            )
        return self._shardings_cache[key]

    def _run_chunk(self, params, seq_len, nfe, chunk, results, pad=True) -> None:
        d = self.dlm.config.d_model
        total = sum(req.batch for _, req, _ in chunk)
        padded = self._bucket_batch(total) if pad else total
        parts = [
            jax.random.normal(
                jax.random.PRNGKey(req.seed),
                (req.batch, seq_len, d),
                jnp.float32,
            )
            for _, req, _ in chunk
        ]
        if padded > total:
            parts.append(jnp.zeros((padded - total, seq_len, d), jnp.float32))
        x_init = jnp.concatenate(parts, axis=0)

        cfg = dataclasses.replace(self.solver_config, nfe=nfe)
        shardings = self._shardings(padded)
        if shardings is not None:
            x_init = jax.device_put(x_init, shardings.x)
            params = self._replicate(params)
        run = self._runner(cfg, padded, seq_len)
        t0 = time.perf_counter()
        if self.solver_name == "era":
            eps_buf, t_buf = era_mod.alloc_buffers(x_init, cfg, shardings)
            x0, aux = run(params, x_init, eps_buf, t_buf)
        else:
            x0, aux = run(params, x_init)
        x0 = jax.block_until_ready(x0)
        wall = time.perf_counter() - t0

        done = time.perf_counter()
        off = 0
        for ticket, req, t_submit in chunk:
            results[ticket] = SampleResult(
                x0=x0[off : off + req.batch],
                aux=self._request_aux(aux, off, req.batch),
                latency_s=done - t_submit,
                batch_wall_s=wall,
                padded_batch=padded,
            )
            off += req.batch

    @staticmethod
    def _request_aux(aux, off: int, batch: int):
        """Scope the solver diagnostics to one request's rows.

        Per-sample runs carry a (nfe, padded_batch) delta_eps history, and
        return_trajectory runs carry (nfe+1, padded_batch, ...) latents; a
        co-batched request must see only its own rows — not its batch-mates'
        (tenant isolation) and not the pad rows, which would also dilute the
        delta_eps mean."""
        per_sample = aux.get("delta_eps_history_per_sample")
        trajectory = aux.get("trajectory")
        if per_sample is None and trajectory is None:
            return aux
        scoped = dict(aux)
        if per_sample is not None:
            rows = per_sample[:, off : off + batch]
            scoped["delta_eps_history_per_sample"] = rows
            scoped["delta_eps_history"] = jnp.mean(rows, axis=-1)
        if trajectory is not None:
            scoped["trajectory"] = trajectory[:, off : off + batch]
        return scoped

    def _runner(self, cfg: SolverConfig, batch: int, seq_len: int):
        """One jitted program per (config, padded-batch, seq_len) bucket.

        Mesh-aware: the key carries the data-parallel size so an engine
        rebuilt on a different mesh never aliases a cached program."""
        key = (self.solver_name, cfg, batch, seq_len, self.dp)
        if key not in self._jitted:
            shardings = self._shardings(batch)
            if self.solver_name == "era":
                # consult the parity gate here, eagerly — the probe cannot
                # run inside the jit trace below, and this is the first ERA
                # touch on a fresh process serving only compiled buckets
                era_mod._fused_ops()

                def run(params, x_init, eps_buf, t_buf):
                    out = era_mod.sample_scan(
                        self.dlm.eps_fn(params),
                        x_init,
                        eps_buf,
                        t_buf,
                        self.schedule,
                        cfg,
                        shardings=shardings,
                    )
                    return out.x0, out.aux

                # donate x + Lagrange buffers so XLA reuses them in place
                # (CPU ignores donation and would warn, so gate it)
                donate = (1, 2, 3) if jax.default_backend() != "cpu" else ()
                self._jitted[key] = jax.jit(run, donate_argnums=donate)
            else:
                sample_fn = get_solver(self.solver_name)

                def run(params, x_init):
                    out = sample_fn(
                        self.dlm.eps_fn(params), x_init, self.schedule, cfg
                    )
                    return out.x0, out.aux

                self._jitted[key] = jax.jit(run)
        return self._jitted[key]

    # ---- introspection (tests / benchmarks) ----------------------------
    def compile_cache(self) -> dict[Any, Any]:
        """Bucket-key -> jitted runner map (each compiles exactly once)."""
        return dict(self._jitted)


class SamplerService:
    """One-call facade over :class:`BatchedSampler` (exact-size buckets)."""

    def __init__(
        self,
        dlm: DiffusionLM,
        schedule: NoiseSchedule,
        solver: str = "era",
        solver_config: SolverConfig | None = None,
        mesh: Mesh | None = None,
    ):
        self.dlm = dlm
        self.schedule = schedule
        self.solver_name = solver
        if solver_config is None:
            solver_config = ERAConfig() if solver == "era" else SolverConfig()
        self.solver_config = solver_config
        self._engine = BatchedSampler(
            dlm, schedule, solver, solver_config, batch_buckets=None, mesh=mesh
        )

    def sample(self, params, req: SampleRequest) -> tuple[Array, dict]:
        """Generate req.batch sequences of latents via the solver."""
        ticket = self._engine.submit(req)
        res = self._engine.drain(params)[ticket]
        return res.x0, {"wall_s": res.batch_wall_s, **res.aux}

    # ---- dry-run hook: the full solver loop as one lowerable program ----
    def sample_program(self):
        sample_fn = get_solver(self.solver_name)
        cfg = self.solver_config

        def program(params, x_init):
            return sample_fn(
                self.dlm.eps_fn(params), x_init, self.schedule, cfg
            ).x0

        return program
