"""Diffusion sampling service: ERA-Solver (or any registered solver) driving
a DiffusionLM denoiser — the paper's deployment shape.

One `SamplerService.sample()` call runs the full solver loop as a single
jitted XLA program (fori_loop over NFE steps, one backbone eval per step for
ERA/DDIM/Adams).  The service also exposes `sample_step_lowerable`, the
entry the dry-run lowers to prove the solver itself distributes (the
Lagrange buffer shards with the latents; the ERS scalar state replicates).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import ERAConfig, NoiseSchedule, SolverConfig, get_solver
from repro.models.diffusion import DiffusionLM

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SampleRequest:
    batch: int
    seq_len: int
    nfe: int = 10
    solver: str = "era"
    seed: int = 0


class SamplerService:
    def __init__(
        self,
        dlm: DiffusionLM,
        schedule: NoiseSchedule,
        solver: str = "era",
        solver_config: SolverConfig | None = None,
    ):
        self.dlm = dlm
        self.schedule = schedule
        self.solver_name = solver
        self.solver_config = solver_config or (
            ERAConfig() if solver == "era" else SolverConfig()
        )
        self._jitted: dict[Any, Any] = {}

    def _runner(self, cfg_key):
        if cfg_key not in self._jitted:
            sample_fn = get_solver(self.solver_name)
            cfg = self.solver_config

            def run(params, x_init):
                out = sample_fn(
                    self.dlm.eps_fn(params), x_init, self.schedule, cfg
                )
                return out.x0, out.aux

            self._jitted[cfg_key] = jax.jit(run)
        return self._jitted[cfg_key]

    def sample(self, params, req: SampleRequest) -> tuple[Array, dict]:
        """Generate req.batch sequences of latents via the solver."""
        key = jax.random.PRNGKey(req.seed)
        x_init = jax.random.normal(
            key, (req.batch, req.seq_len, self.dlm.config.d_model), jnp.float32
        )
        cfg = dataclasses.replace(self.solver_config, nfe=req.nfe)
        self.solver_config = cfg
        run = self._runner((req.nfe, req.batch, req.seq_len))
        t0 = time.perf_counter()
        x0, aux = run(params, x_init)
        x0 = jax.block_until_ready(x0)
        wall = time.perf_counter() - t0
        return x0, {"wall_s": wall, **aux}

    # ---- dry-run hook: the full solver loop as one lowerable program ----
    def sample_program(self):
        sample_fn = get_solver(self.solver_name)
        cfg = self.solver_config

        def program(params, x_init):
            return sample_fn(
                self.dlm.eps_fn(params), x_init, self.schedule, cfg
            ).x0

        return program
