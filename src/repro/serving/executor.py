"""Thread-safe fused-execution core of the diffusion sampling engine.

:class:`FusedExecutor` owns everything below the request queue: request
validation, bucket selection, mesh placement, the jit cache (one compiled
program per (solver, config, padded-batch, seq_len) bucket), chunk
execution, and per-request aux scoping.  Both entry points share one
executor instance:

* the sync :class:`~repro.serving.diffusion_sampler.BatchedSampler.drain`
  path, which fuses whatever is pending at call time, and
* the continuous-batching
  :class:`~repro.serving.scheduler.AsyncBatchedSampler`, whose background
  drain thread fuses requests across arrival time.

The executor is **solver-agnostic**: every registry solver is a
:class:`~repro.core.SolverProgram` (scan entry + donatable buffers + carry
shardings + request policy), so there are no solver-specific branches here.
``SampleRequest.solver`` routes each request to its program — one executor
serves a mixed ``era`` / ``ddim`` / ``dpm_solver_pp2m`` / ... stream, with
requests batched per solver (the jit cache and the scheduler's fuse queues
key on ``(solver, seq_len, nfe)``, so mixed traffic never cross-contaminates
a bucket).

**Seq-len bucketing** (``seq_buckets=(64, 128, ...)``): requests whose
``seq_len`` differ can fuse into one compiled batch.  Each request's rows
are right-padded on the host from their exact length to the smallest bucket
that fits, a per-row ``lengths`` vector rides through the compiled program,
the denoiser masks pad keys out of every attention softmax
(``DiffusionLM.eps(lengths=...)``), and length-aware solver programs mask
their own sequence reductions (ERA's ERS error norms, which accumulate
positions sequentially so padding cannot re-associate them).  Padded runs
are therefore *mathematically* identical to exact-shape runs everywhere,
and **bit-identical** wherever the denoiser itself adds no
padded-length reductions — positionwise denoisers (the property walls),
and in practice the attention stacks on the CPU test shapes; the
guaranteed bar for attention denoisers is the 1e-6 parity wall, since XLA
may re-associate a softmax reduction over a padded key axis.
The group key then carries the *bucketed* length, bounding the compile
count by the bucket ladder rather than by distinct seq_lens.  Bucketing
silently falls back to exact-shape grouping per solver when masking can't
be guaranteed: non-fusable configs (exact-size runs can't pad), programs
that don't support lengths, or denoisers whose block stack isn't maskable
(``DiffusionLM.supports_length_masking``).

**NFE bucketing** (``nfe_buckets=(16, 32, ...)``): requests whose ``nfe``
differ can also fuse into one compiled batch.  The fuse key carries the
request's NFE *bucket* (the smallest ladder entry >= its nfe), the
compiled scan runs the bucket's step count, and a per-row
:class:`~repro.core.program.StepMask` rides through the program: each
row carries its own step count and its own time grid (the exact
``step_times`` floats its unpadded run uses, terminal-padded), and a row
whose steps are spent freezes **bitwise** — its remaining scan iterations
leave its entire carry unchanged.  The jit cache and warmup grid are then
bounded by ``|solvers| x |seq_buckets| x |nfe_buckets|`` instead of by
distinct request NFEs.  With a ladder configured, *all* of a
steps-capable solver's traffic routes through the step-masked program
(uniform batches run fully active) — the bitwise invariance bar holds
between step-masked runs at one padded batch bucket, so the engine never
mixes the scalar-time static path into a bucketed stream.  Per-solver
fallback to exact-NFE grouping mirrors seq bucketing: non-fusable
configs, and programs without a step-masked scan
(``SolverProgram.supports_steps``; e.g. the Python-unrolled
``dpm_solver_fast`` plan), counted on ``sampler_masked_fallback_total``
with ``impl="nfe-bucketing"``.  ``sampler_nfe_padding_rows_total``
counts rows that ran a larger bucket than they asked for (the padding
waste a too-coarse ladder buys).

All mutable state (jit cache, shardings cache, param replication cache) is
guarded by one re-entrant lock, and chunk execution itself is serialized
under the same lock — concurrent ``drain()`` callers and the scheduler
thread can share an executor without double-compiling a bucket or
interleaving donated-buffer executions.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
import warnings
import weakref
from concurrent.futures import Future, InvalidStateError
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import NoiseSchedule, SolverConfig, get_program
from repro.core.program import SolverProgram, StepMask
from repro.models import attention as _attention
from repro.models.diffusion import DiffusionLM
from repro.parallel.sharding import (
    ParamReplicator,
    dp_size,
    round_to_dp,
)
from repro.serving import result_keys as K
from repro.serving.compile_cache import disk_cache_hits
from repro.serving.metrics import MetricsRegistry

Array = jax.Array

#: ``jax.random.PRNGKey`` folds the seed into an int64 — anything outside
#: this range raises OverflowError at *drain* time, inside a fused batch,
#: which would fail every co-batched request.  validate() rejects it at
#: submit instead (JSON ints are unbounded, so the wire can send anything).
SEED_MIN = -(2**63)
SEED_MAX = 2**63 - 1

#: Server-side ceilings on the wire-exposed resource fields.  Without
#: them a single request (``batch=10**8``, ``nfe=10**7``, or an enormous
#: ``seq_len`` on an engine with no seq-bucket ladder) forces a multi-GB
#: host allocation, a pathological XLA compile, or an unbounded jit cache
#: at drain time — after admission, where the failure takes down
#: batch-mates.  Engines accept ``None`` to opt out (trusted in-process
#: callers); the defaults are far above every serving shape in the repo.
DEFAULT_MAX_BATCH = 4096
DEFAULT_MAX_NFE = 1000
DEFAULT_MAX_SEQ_LEN = 8192


@dataclasses.dataclass(frozen=True)
class SampleRequest:
    """One sampling request, as submitted to any serving entry point.

    Immutable and hashable — safe to share across threads, reuse for
    resubmission, and use in test fixtures.  Requests are validated at
    ``submit()`` (never at drain time): unknown ``solver`` names, per-solver
    ``(batch, nfe)`` constraints, seeds outside the int64 range
    ``PRNGKey`` accepts, the engine's ``max_batch`` / ``max_nfe`` /
    ``max_seq_len`` resource ceilings, and — when the engine has seq
    buckets — ``seq_len`` above the largest bucket are all rejected there,
    so an invalid request can never poison a fused batch for its
    co-batched neighbours.

    ``seed`` fully determines the request's initial noise: ``x_T`` is drawn
    as ``jax.random.normal(PRNGKey(seed), (batch, seq_len, d_model))``
    regardless of which fused batch, seq bucket, or mesh the request lands
    in — this is what the arrival-determinism and padding-invariance walls
    pin down.

    ``priority`` and ``deadline_ms`` are scheduling hints honored by the
    continuous-batching drain policy (and carried verbatim over the wire
    by the front door): when a fuse-group queue launches, higher-priority
    requests board the batch first; a request still queued
    ``deadline_ms`` after submit fails fast with
    :class:`~repro.serving.scheduler.DeadlineExceededError` instead of
    occupying a fused batch.  Neither field affects results — a request's
    ``x0`` depends only on ``(seed, seq_len, nfe, solver)``.  The sync
    ``drain()`` path runs everything pending, so both are no-ops there.
    """

    batch: int
    seq_len: int
    nfe: int = 10
    # registry solver this request routes to; None = the engine's default
    # solver.  Unknown names are rejected at submit(), not drain time.
    solver: str | None = None
    seed: int = 0
    # scheduling hints (continuous-batching drain policy; see class doc)
    priority: int = 0
    deadline_ms: float | None = None


@dataclasses.dataclass
class SampleResult:
    """Per-request output of a drained batch.

    Delivered through the request's Future by whichever thread drained the
    fused batch.  ``x0`` and every ``aux`` entry are scoped to this
    request alone: its own rows (no batch-mates, no pad rows) and — under
    seq bucketing — its own ``seq_len`` positions (no pad positions).
    ``batch_wall_s`` / ``padded_batch`` / ``padded_seq_len`` describe the
    fused batch the request rode in and are shared by its batch-mates;
    ``latency_s`` is this request's own submit→result wall time.

    This is the **one** result type across the stack: engine drains, the
    scheduler's futures, ``SamplerService.sample``, and the front door's
    wire schema all carry exactly this dataclass.  :attr:`info` flattens
    the telemetry fields plus ``aux`` into one dict under the documented
    :mod:`~repro.serving.result_keys` keys (what the facade used to return
    as the second tuple element).  Tuple unpacking ``x0, info = result``
    still works as a deprecated shim.
    """

    x0: Array                # (batch, seq_len, d_model)
    aux: dict[str, Any]      # solver diagnostics, scoped to this request's
                             # rows (per-sample histories / trajectories
                             # exclude batch-mates and pad rows) and valid
                             # positions (trajectories exclude pad tail)
    latency_s: float         # submit -> result wall time
    batch_wall_s: float      # wall time of the fused batch this rode in
    padded_batch: int        # batch bucket size the batch ran at
    padded_seq_len: int      # seq length the batch ran at (== seq bucket
                             # under seq bucketing, else the exact seq_len)
    padded_nfe: int          # NFE budget the batch scanned to (== nfe
                             # bucket under NFE bucketing, else exact nfe;
                             # this request's surplus steps were inert)

    @property
    def info(self) -> dict[str, Any]:
        """Engine telemetry + solver ``aux`` as one dict, keyed by the
        :mod:`~repro.serving.result_keys` constants."""
        return {
            K.WALL_S: self.batch_wall_s,
            K.LATENCY_S: self.latency_s,
            K.PADDED_BATCH: self.padded_batch,
            K.PADDED_SEQ_LEN: self.padded_seq_len,
            K.PADDED_NFE: self.padded_nfe,
            **self.aux,
        }

    # ---- deprecated (x0, info) tuple shim -------------------------------
    def _tuple_shim(self):
        warnings.warn(
            "tuple unpacking of SampleResult is deprecated; use "
            "result.x0 and result.info",
            DeprecationWarning,
            stacklevel=3,
        )
        return (self.x0, self.info)

    def __iter__(self):
        return iter(self._tuple_shim())

    def __getitem__(self, i):
        return self._tuple_shim()[i]


# A queued request: (ticket, request, submit-time).  Both the sync engine's
# pending list and the scheduler's per-shape queues carry this shape, so the
# executor can run a chunk from either source.
QueueItem = tuple[int, SampleRequest, float]


def resolve_future(fut: Future, result=None, exception=None) -> None:
    """Resolve a delivery future, tolerating client-side cancellation.

    A waiter that gave up (``fut.cancel()`` after a result() timeout) leaves
    the future in CANCELLED state; ``set_result``/``set_exception`` on it
    raises InvalidStateError, which must not take down the drain path — the
    other requests in the batch still have live waiters.
    """
    try:
        if exception is not None:
            fut.set_exception(exception)
        else:
            fut.set_result(result)
    except InvalidStateError:
        pass


class FusedExecutor:
    """Fused-chunk runner shared by the sync drain path and the scheduler.

    Thread-safety contract: every public method may be called from any
    thread.  Reads of the jit / shardings / replication caches and chunk
    execution itself serialize under one re-entrant lock, so sync
    ``drain()`` callers and the scheduler's drain thread share compiled
    buckets without double-compiling or interleaving donated-buffer
    executions; ``run_chunk`` blocks for the whole fused execution
    (device-synchronous — it calls ``block_until_ready``).

    ``seq_buckets`` (e.g. ``(64, 128, 256, 512)``) opts into mixed-seq-len
    fusion: see the module docstring for the masking contract and the
    exact-shape fallbacks.  ``None`` (default) groups by exact ``seq_len``.
    """

    def __init__(
        self,
        dlm: DiffusionLM,
        schedule: NoiseSchedule,
        solver: str = "era",
        solver_config: SolverConfig | None = None,
        batch_buckets: tuple[int, ...] | None = (1, 8, 64),
        mesh: Mesh | None = None,
        seq_buckets: tuple[int, ...] | None = None,
        nfe_buckets: tuple[int, ...] | None = None,
        metrics: MetricsRegistry | None = None,
        max_batch: int | None = DEFAULT_MAX_BATCH,
        max_nfe: int | None = DEFAULT_MAX_NFE,
        max_seq_len: int | None = DEFAULT_MAX_SEQ_LEN,
    ):
        self.dlm = dlm
        self.max_batch = max_batch
        self.max_nfe = max_nfe
        self.max_seq_len = max_seq_len
        self.schedule = schedule
        self.solver_name = solver
        # per-solver engine configs: the constructor pins the default
        # solver's config; other solvers a request routes to lazily get
        # their program's engine default (e.g. per-sample ERS for ERA)
        self._configs: dict[str, SolverConfig] = {}
        self._configs[solver] = (
            get_program(solver).engine_config()
            if solver_config is None
            else solver_config
        )
        self.mesh = mesh
        self.dp = dp_size(mesh) if mesh is not None else 1
        if batch_buckets:
            # every fused batch must split evenly over the data axes, so
            # buckets round up to dp multiples (1/8/64 on dp=8 -> 8/64)
            batch_buckets = sorted({round_to_dp(b, mesh) for b in batch_buckets})
        self.batch_buckets = tuple(batch_buckets) if batch_buckets else None
        self.seq_buckets = tuple(sorted(seq_buckets)) if seq_buckets else None
        self.nfe_buckets = tuple(sorted(nfe_buckets)) if nfe_buckets else None
        # per-solver verdict: may this solver's traffic seq-bucket at all?
        self._seq_masked: dict[str, bool] = {}
        # per-solver verdict: may this solver's traffic nfe-bucket at all?
        self._nfe_masked: dict[str, bool] = {}
        # host-side (solver, nfe) -> per-row time grid cache (the StepMask
        # rows every chunk of that solver/nfe reuses)
        self._row_times: dict[tuple[str, int], np.ndarray] = {}
        self._jitted: dict[Any, Any] = {}
        self._shardings_cache: dict[Any, Any] = {}
        self._replicate = ParamReplicator(mesh) if mesh is not None else None
        self._lock = threading.RLock()
        # one registry per executor: the scheduler and front door instrument
        # into the same scrape (get-or-create registration, so sharing is
        # idempotent).  Everything below is cheap host-side accounting.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_compile_hits = self.metrics.counter(
            "sampler_compile_cache_hits_total",
            "fused chunks served by an already-compiled bucket program "
            "(in-process executable cache)",
        )
        self._m_compile_misses = self.metrics.counter(
            "sampler_compile_cache_misses_total",
            "bucket programs built at the lower/compile boundary, labelled "
            "by source: disk (persistent compilation cache) or fresh "
            "(real XLA compile)",
        )
        self._m_compile_programs = self.metrics.counter(
            "sampler_compile_programs_total",
            "program acquisitions by source: memory (in-process "
            "executable cache), disk (persistent compilation cache), "
            "fresh (real XLA compile)",
        )
        self._m_compile_wall = self.metrics.histogram(
            "sampler_compile_seconds",
            "wall time of each lower+compile at the AOT boundary",
            buckets=(0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0),
        )
        self._m_warmup_total = self.metrics.gauge(
            "sampler_warmup_grid_programs",
            "programs in the configured warmup grid (0 until warmup() runs)",
        )
        self._m_warmup_done = self.metrics.gauge(
            "sampler_warmup_compiled_programs",
            "warmup grid programs compiled so far",
        )
        self._m_warmup_inflight = self.metrics.gauge(
            "sampler_warmup_in_progress",
            "1 while warmup() is compiling the grid",
        )
        self._m_warmup_wall = self.metrics.gauge(
            "sampler_warmup_duration_seconds",
            "wall time of the last completed warmup()",
        )
        self._m_warmup_programs = self.metrics.counter(
            "sampler_warmup_programs_total",
            "programs compiled by warmup(), by solver",
        )
        # plain-python mirror of the source-labelled compile counters, for
        # callers (tests, bench_coldstart) that want exact counts without
        # scraping label combinations out of the registry
        self._compile_counts = {"fresh": 0, "disk": 0, "memory": 0}
        self._warmup_state: dict[str, Any] = {"state": "none", "done": 0, "total": 0}
        self._m_batches = self.metrics.counter(
            "sampler_batches_total", "fused batches executed"
        )
        self._m_rows = self.metrics.counter(
            "sampler_batch_rows_total",
            "real (non-pad) request rows executed across fused batches",
        )
        self._m_occupancy = self.metrics.histogram(
            "sampler_fuse_occupancy_ratio",
            "real rows / padded rows per fused batch (1.0 = no pad waste)",
            buckets=(0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0),
        )
        self._m_wall = self.metrics.histogram(
            "sampler_batch_wall_seconds", "device wall time per fused batch"
        )
        # the permanent canary that masked (mixed-seq-len) traffic regressed
        # off the fast path.  Two sources feed it: sdpa rewriting a requested
        # fast impl to chunked (impl = the requested attention kernel; fires
        # at trace time, one count per compiled program that materialized on
        # the slow path), and the engine's seq-bucketing verdict falling back
        # to exact-shape grouping (impl = "seq-bucketing"; once per solver).
        # A healthy dense/pallas deployment holds this at zero.
        self._m_masked_fallback = self.metrics.counter(
            "sampler_masked_fallback_total",
            "masked-traffic fast-path fallbacks by requested impl and "
            "reason: sdpa fast-kernel rewrites to chunked, and engine "
            "seq-bucketing / nfe-bucketing verdicts that force exact-shape "
            "or exact-NFE grouping",
        )
        # NFE-padding waste: real request rows that ran a larger nfe bucket
        # than they asked for (their tail steps are per-row frozen no-ops).
        # A ladder tuned to the traffic holds this near zero.
        self._m_nfe_pad_rows = self.metrics.counter(
            "sampler_nfe_padding_rows_total",
            "request rows padded to a larger NFE bucket than requested "
            "(per-row step masks freeze their surplus steps)",
        )
        # weakref so a dropped executor never keeps itself alive through the
        # module-level observer list; a dead ref unregisters itself on fire
        self_ref = weakref.ref(self)

        def _on_sdpa_fallback(impl: str, reason: str) -> None:
            ex = self_ref()
            if ex is None:
                _attention.unregister_fallback_observer(_on_sdpa_fallback)
                return
            ex._m_masked_fallback.inc(impl=impl, reason=reason)

        _attention.register_fallback_observer(_on_sdpa_fallback)
        self._sdpa_fallback_observer = _on_sdpa_fallback

    # ---- solver routing --------------------------------------------------
    def resolve_solver(self, req: SampleRequest) -> str:
        """The registry name this request routes to."""
        return req.solver or self.solver_name

    def program_for(self, solver: str | None) -> SolverProgram:
        return get_program(solver or self.solver_name)

    def config_for(self, solver: str | None) -> SolverConfig:
        name = solver or self.solver_name
        cfg = self._configs.get(name)
        if cfg is None:
            cfg = self._configs[name] = get_program(name).engine_config()
        return cfg

    @property
    def solver_config(self) -> SolverConfig:
        """The engine's default solver's config (back-compat surface)."""
        return self.config_for(self.solver_name)

    # ---- request policy --------------------------------------------------
    @property
    def fusable(self) -> bool:
        """Can strangers (and pad rows) share a batch under the default
        solver's config?  (Per-request: :meth:`fusable_for`.)"""
        return self.fusable_for(None)

    def fusable_for(self, solver: str | None) -> bool:
        return self.program_for(solver).fusable(self.config_for(solver))

    @property
    def max_bucket(self) -> int | None:
        return self.batch_buckets[-1] if self.batch_buckets else None

    # ---- seq-len bucketing ----------------------------------------------
    def seq_masked(self, solver: str | None) -> bool:
        """Does this solver's traffic fuse across seq_lens (padded +
        length-masked), or fall back to exact-shape grouping?

        Requires *every* layer of the masking contract: an engine bucket
        ladder, a fusable config (exact-size runs cannot pad), a program
        that guarantees pad positions never leak into valid ones
        (``SolverProgram.supports_lengths``), and a denoiser whose block
        stack can be masked exactly
        (``DiffusionLM.supports_length_masking``)."""
        if not self.seq_buckets:
            return False
        name = solver or self.solver_name
        verdict = self._seq_masked.get(name)
        if verdict is None:
            program = self.program_for(name)
            cfg = self.config_for(name)
            fusable = program.fusable(cfg)
            lengths_ok = program.supports_lengths(cfg)
            maskable = bool(getattr(self.dlm, "supports_length_masking", False))
            verdict = self._seq_masked[name] = (
                fusable and lengths_ok and maskable
            )
            if not verdict:
                # exact-shape grouping is the engine-level slow path; count
                # it on the same canary the sdpa kernel fallbacks feed
                reason = (
                    "non-fusable-config" if not fusable
                    else "program-no-lengths" if not lengths_ok
                    else "denoiser-unmaskable"
                )
                self._m_masked_fallback.inc(impl="seq-bucketing", reason=reason)
        return verdict

    def bucket_seq(self, n: int) -> int:
        """Smallest seq bucket >= n (requests above the ladder are rejected
        at submit, so this never falls off the end)."""
        for s in self.seq_buckets:
            if n <= s:
                return s
        raise ValueError(
            f"seq_len {n} exceeds the largest seq bucket "
            f"{self.seq_buckets[-1]}"
        )

    # ---- NFE bucketing ---------------------------------------------------
    def nfe_masked(self, solver: str | None) -> bool:
        """Does this solver's traffic fuse across NFEs (scanning to the
        bucketed step count under a per-row step mask), or fall back to
        exact-NFE grouping?

        Requires an engine nfe-bucket ladder, a fusable config (exact-size
        runs cannot pad — in steps any more than in rows), and a program
        with a step-masked scan (``SolverProgram.supports_steps``: per-row
        times through every coefficient, spent rows frozen bitwise)."""
        if not self.nfe_buckets:
            return False
        name = solver or self.solver_name
        verdict = self._nfe_masked.get(name)
        if verdict is None:
            program = self.program_for(name)
            cfg = self.config_for(name)
            fusable = program.fusable(cfg)
            steps_ok = program.supports_steps(cfg)
            verdict = self._nfe_masked[name] = fusable and steps_ok
            if not verdict:
                # exact-NFE grouping is the engine-level slow path; count it
                # on the same canary the seq-bucketing fallbacks feed
                reason = (
                    "non-fusable-config" if not fusable
                    else "program-no-steps"
                )
                self._m_masked_fallback.inc(impl="nfe-bucketing", reason=reason)
        return verdict

    def bucket_nfe(self, n: int) -> int:
        """Smallest nfe bucket >= n (requests above the ladder are rejected
        at submit, so this never falls off the end)."""
        for b in self.nfe_buckets:
            if n <= b:
                return b
        raise ValueError(
            f"nfe {n} exceeds the largest nfe bucket {self.nfe_buckets[-1]}"
        )

    def group_key(self, req: SampleRequest) -> tuple[str, int, int]:
        """The fuse-group key ``(solver, seq, nfe)`` — what the sync
        drain's groups, the scheduler's queues, and the jit cache batch by.
        Under seq bucketing ``seq`` is the request's seq *bucket*, so
        mixed-length traffic shares a group and the compile count is
        bounded by the ladder; otherwise it is the exact ``seq_len``.
        Under NFE bucketing ``nfe`` is likewise the request's NFE *bucket*,
        so mixed-NFE traffic shares a group (and one compiled, step-masked
        program); otherwise it is the exact ``nfe``."""
        solver = self.resolve_solver(req)
        seq = (
            self.bucket_seq(req.seq_len)
            if self.seq_masked(solver)
            else req.seq_len
        )
        nfe = (
            self.bucket_nfe(req.nfe)
            if self.nfe_masked(solver)
            else req.nfe
        )
        return (solver, seq, nfe)

    def validate(self, req: SampleRequest) -> None:
        """Reject an invalid request at submit time, not drain time — a bad
        request must not poison the queue for its co-batched neighbours.
        Unknown solver names fail here; per-solver (batch, nfe) constraints
        live in each program's ``validate``."""
        if req.batch < 1:
            raise ValueError(f"batch must be >= 1, got {req.batch}")
        if self.max_batch is not None and req.batch > self.max_batch:
            raise ValueError(
                f"batch {req.batch} exceeds the engine's max_batch "
                f"{self.max_batch}"
            )
        if req.seq_len < 1:
            raise ValueError(f"seq_len must be >= 1, got {req.seq_len}")
        if self.max_nfe is not None and req.nfe > self.max_nfe:
            raise ValueError(
                f"nfe {req.nfe} exceeds the engine's max_nfe {self.max_nfe}"
            )
        if self.nfe_buckets and req.nfe > self.nfe_buckets[-1]:
            # same serving contract as the seq ladder: an over-budget
            # request would need its own compiled step count, which is
            # exactly the fragmentation NFE bucketing exists to prevent
            raise ValueError(
                f"nfe {req.nfe} exceeds the largest nfe bucket "
                f"{self.nfe_buckets[-1]}; extend nfe_buckets or submit "
                f"requests within the ladder"
            )
        if self.seq_buckets and req.seq_len > self.seq_buckets[-1]:
            # the bucket ladder is the engine's serving contract: an
            # over-long request would need its own compiled shape, which is
            # exactly the fragmentation bucketing exists to prevent
            raise ValueError(
                f"seq_len {req.seq_len} exceeds the largest seq bucket "
                f"{self.seq_buckets[-1]}; extend seq_buckets or submit "
                f"requests within the ladder"
            )
        if (
            not self.seq_buckets
            and self.max_seq_len is not None
            and req.seq_len > self.max_seq_len
        ):
            # no ladder bounds the compile cache here — every distinct
            # seq_len compiles its own program, so cap the axis outright
            raise ValueError(
                f"seq_len {req.seq_len} exceeds the engine's max_seq_len "
                f"{self.max_seq_len}"
            )
        if not isinstance(req.seed, int) or isinstance(req.seed, bool):
            raise ValueError(f"seed must be an int, got {req.seed!r}")
        if not SEED_MIN <= req.seed <= SEED_MAX:
            # PRNGKey(seed) overflows outside int64 — at drain time, inside
            # a fused batch, failing every co-batched neighbour
            raise ValueError(
                f"seed must fit in a signed 64-bit integer "
                f"({SEED_MIN} <= seed <= {SEED_MAX}), got {req.seed}"
            )
        if not isinstance(req.priority, int) or isinstance(req.priority, bool):
            raise ValueError(
                f"priority must be an int, got {req.priority!r}"
            )
        if req.deadline_ms is not None:
            ok = (
                isinstance(req.deadline_ms, (int, float))
                and not isinstance(req.deadline_ms, bool)
                and math.isfinite(req.deadline_ms)
                and req.deadline_ms > 0
            )
            if not ok:
                raise ValueError(
                    f"deadline_ms must be a positive finite number of "
                    f"milliseconds (or None), got {req.deadline_ms!r}"
                )
        program = self.program_for(req.solver)  # unknown solver raises here
        program.validate(req, self.config_for(req.solver), dp=self.dp)

    def pack(self, items: list[QueueItem]) -> list[tuple[list[QueueItem], bool]]:
        """Split same-(solver, seq_len, nfe) items into executable chunks.

        Fusable configs pack greedily up to the largest batch bucket;
        non-fusable configs get one exact-size (unpadded) chunk per request.
        Returns ``(chunk, pad)`` pairs.
        """
        if not items:
            return []
        if not self.fusable_for(items[0][1].solver):
            return [([item], False) for item in items]
        chunks: list[tuple[list[QueueItem], bool]] = []
        chunk: list[QueueItem] = []
        total = 0
        for item in items:
            b = item[1].batch
            if chunk and self.max_bucket and total + b > self.max_bucket:
                chunks.append((chunk, True))
                chunk, total = [], 0
            chunk.append(item)
            total += b
        if chunk:
            chunks.append((chunk, True))
        return chunks

    # ---- fused execution -----------------------------------------------
    def bucket_batch(self, n: int) -> int:
        if not self.batch_buckets:
            return round_to_dp(n, self.mesh)
        for b in self.batch_buckets:
            if n <= b:
                return b
        # oversize request: exact-size compile (dp-rounded on a mesh)
        return round_to_dp(n, self.mesh)

    # ---- mesh placement ------------------------------------------------
    def _shardings(self, program: SolverProgram, cfg: SolverConfig, batch: int):
        """Carry shardings for a padded batch (None off-mesh), via the
        program's carry-pspec hook."""
        if self.mesh is None:
            return None
        key = (batch, program.per_sample_state(cfg))
        if key not in self._shardings_cache:
            self._shardings_cache[key] = program.carry_shardings(
                cfg, self.mesh, batch=batch
            )
        return self._shardings_cache[key]

    def run_chunk(
        self,
        params,
        seq_len: int,
        nfe: int,
        chunk: list[QueueItem],
        results: dict[int, SampleResult],
        pad: bool = True,
    ) -> None:
        """Run one chunk as a single fused program; fill ``results`` by
        ticket.  All requests in a chunk share one group key (the queues
        and drain groups key on it): one solver, and one seq length —
        exact, or the shared seq bucket ``seq_len`` each request's rows are
        right-padded up to.  Serialized under the executor lock — safe
        to call from the scheduler thread and sync drain() callers
        concurrently; blocks until the fused result is on host."""
        with self._lock:
            self._run_chunk_locked(params, seq_len, nfe, chunk, results, pad)

    def _step_times_host(self, solver: str, nfe: int) -> np.ndarray:
        """The host-side per-row time grid for one (solver, nfe) — the
        exact ``step_times`` floats an unpadded run of that budget steps
        through, cached so chunk assembly never re-derives a grid."""
        key = (solver, nfe)
        ts = self._row_times.get(key)
        if ts is None:
            program = self.program_for(solver)
            cfg = self.config_for(solver)
            ts = self._row_times[key] = np.asarray(
                program.step_times(self.schedule, nfe, cfg), np.float32
            )
        return ts

    def _run_chunk_locked(self, params, seq_len, nfe, chunk, results, pad):
        d = self.dlm.config.d_model
        solver = self.resolve_solver(chunk[0][1])
        program = self.program_for(solver)
        masked = self.seq_masked(solver)
        stepped = self.nfe_masked(solver)
        total = sum(req.batch for _, req, _ in chunk)
        padded = self.bucket_batch(total) if pad else total
        # assemble the batch on the host: eager jnp.concatenate would XLA-
        # compile once per chunk *composition* (request sizes + pad rows),
        # and under continuous batching every drain can have a new
        # composition — 40-90ms of compile against a ~10ms solver run.
        # Per-request noise stays jax.random (seed-deterministic across
        # batch compositions); numpy does the composition-shaped work.
        # Seq bucketing: each request's noise is drawn at its *exact*
        # (batch, seq_len, d) shape — identical to its solo run — and
        # right-padded with zero rows up to the chunk's seq bucket.
        parts = []
        row_lengths: list[int] = []
        for _, req, _ in chunk:
            noise = np.asarray(
                jax.random.normal(
                    jax.random.PRNGKey(req.seed),
                    (req.batch, req.seq_len, d),
                    jnp.float32,
                )
            )
            if req.seq_len < seq_len:
                noise = np.concatenate(
                    [
                        noise,
                        np.zeros(
                            (req.batch, seq_len - req.seq_len, d), np.float32
                        ),
                    ],
                    axis=1,
                )
            parts.append(noise)
            row_lengths += [req.seq_len] * req.batch
        if padded > total:
            parts.append(np.zeros((padded - total, seq_len, d), np.float32))
            # pad rows are fully "valid": their lanes run ordinary (masked)
            # math on zeros and are sliced away, never a 0-length edge case
            row_lengths += [seq_len] * (padded - total)
        x_init = jnp.asarray(np.concatenate(parts, axis=0))
        lengths = (
            jnp.asarray(np.asarray(row_lengths, np.int32)) if masked else None
        )

        cfg = dataclasses.replace(self.config_for(solver), nfe=nfe)
        # mixed-NFE fusion: assemble the per-row StepMask on the host.  The
        # chunk's ``nfe`` is the group's NFE *bucket*; each request row
        # carries its own step count and its own exact-NFE time grid
        # (terminal-padded to the bucket's step count), so its active
        # prefix computes the very floats its unpadded run would.  Batch
        # pad rows run fully active on the bucket grid — ordinary masked
        # math on zeros, never a 0-step edge case.
        steps = None
        if stepped:
            cap = program.steps_for_nfe(nfe, cfg)
            acts: list[int] = []
            rows_ts: list[np.ndarray] = []
            nfe_padded_rows = 0
            for _, req, _ in chunk:
                n_r = program.steps_for_nfe(req.nfe, cfg)
                ts_r = self._step_times_host(solver, req.nfe)
                if n_r < cap:
                    ts_r = np.concatenate(
                        [ts_r, np.full((cap - n_r,), ts_r[-1], np.float32)]
                    )
                    nfe_padded_rows += req.batch
                acts += [n_r] * req.batch
                rows_ts += [ts_r] * req.batch
            if padded > total:
                bucket_ts = self._step_times_host(solver, nfe)
                acts += [cap] * (padded - total)
                rows_ts += [bucket_ts] * (padded - total)
            steps = StepMask(
                active_steps=jnp.asarray(np.asarray(acts, np.int32)),
                ts=jnp.asarray(np.stack(rows_ts, axis=0)),
            )
            if nfe_padded_rows:
                self._m_nfe_pad_rows.inc(nfe_padded_rows, solver=solver)
        shardings = self._shardings(program, cfg, padded)
        if shardings is not None:
            x_init = jax.device_put(x_init, shardings.x)
            if lengths is not None:
                lengths = jax.device_put(lengths, shardings.lengths)
            if steps is not None:
                steps = StepMask(
                    active_steps=jax.device_put(
                        steps.active_steps, shardings.active_steps
                    ),
                    ts=jax.device_put(steps.ts, shardings.step_ts),
                )
            params = self._replicate(params)
        run = self._jit_for(solver, cfg, padded, seq_len, masked, stepped, params)
        t0 = time.perf_counter()
        buffers = program.alloc_buffers(x_init, cfg, shardings)
        x0, aux = run(params, x_init, lengths, steps, *buffers)
        x0 = jax.block_until_ready(x0)
        wall = time.perf_counter() - t0
        self._m_batches.inc()
        self._m_rows.inc(total)
        self._m_occupancy.observe(total / padded, solver=solver)
        self._m_wall.observe(wall, solver=solver)

        done = time.perf_counter()
        off = 0
        for ticket, req, t_submit in chunk:
            x0_req = x0[off : off + req.batch]
            scope_seq = None
            if masked and req.seq_len < seq_len:
                x0_req = x0_req[:, : req.seq_len]
                scope_seq = req.seq_len
            results[ticket] = SampleResult(
                x0=x0_req,
                aux=program.scope_aux(
                    aux, off, req.batch, seq_len=scope_seq,
                    # under NFE bucketing the scan ran the bucket's step
                    # count; step-stacked aux drops this request's inert
                    # tail so histories match the unpadded run's shape
                    n_steps=(
                        program.steps_for_nfe(req.nfe, cfg)
                        if stepped else None
                    ),
                    padded_steps=(
                        program.steps_for_nfe(nfe, cfg) if stepped else None
                    ),
                ),
                latency_s=done - t_submit,
                batch_wall_s=wall,
                padded_batch=padded,
                padded_seq_len=seq_len,
                padded_nfe=nfe,
            )
            off += req.batch

    def _jit_for(
        self, solver: str, cfg: SolverConfig, batch: int, seq_len: int,
        masked: bool, stepped: bool, params,
    ):
        """One compiled executable per (solver, config, padded-batch,
        seq_len) bucket — with ``seq_len`` a ladder bucket under seq
        bucketing, so the cache size is bounded by the ladder, not by
        distinct request lengths.  The per-row ``lengths`` vector is a
        runtime *argument* of the compiled program (None on unmasked
        buckets), so any mix of request lengths reuses one executable.

        Programs are compiled ahead of time (``lower().compile()`` at this
        boundary, in :meth:`_compile`) rather than deferred to a lazy
        ``jax.jit`` wrapper's first call — so ``warmup()`` can populate
        the same cache from abstract shapes without sampling, and a cache
        miss here *is* the compile, correctly labelled ``disk`` vs
        ``fresh``.

        Under NFE bucketing the per-row :class:`StepMask` is likewise a
        runtime argument (None on unstepped buckets): ``cfg.nfe`` is the
        group's NFE *bucket*, so any mix of request NFEs within the bucket
        reuses one executable and the cache stays bounded by
        ``|solvers| x |seq_buckets| x |nfe_buckets|``.

        Mesh-aware: the key carries the data-parallel size so an engine
        rebuilt on a different mesh never aliases a cached program; it also
        carries ``masked`` / ``stepped`` so an exact-shape or exact-NFE
        group never aliases a masked/step-masked program of the same
        shape."""
        key = (solver, cfg, batch, seq_len, self.dp, masked, stepped)
        cached = self._jitted.get(key)
        if cached is not None:
            self._m_compile_hits.inc(solver=solver)
            self._m_compile_programs.inc(solver=solver, source="memory")
            self._compile_counts["memory"] += 1
            return cached
        compiled, _ = self._compile(key, params)
        return compiled

    def _compile(self, key, params):
        """Lower and compile one bucket program from abstract shapes — no
        sampling, no params traffic — and cache the executable under
        ``key``.  Returns ``(compiled, source)`` with ``source`` ``"disk"``
        (served by the persistent compilation cache) or ``"fresh"`` (real
        XLA compile).  Callers hold the executor lock."""
        solver, cfg, batch, seq_len, _, masked, stepped = key
        program = self.program_for(solver)
        shardings = self._shardings(program, cfg, batch)
        # eager pre-compile hook: probes that cannot run inside the jit
        # trace below (ERA's fused-kernel parity gate)
        program.pre_compile(cfg)

        def run(params, x_init, lengths, steps, *buffers):
            eps_fn = (
                self.dlm.eps_fn(params)
                if lengths is None
                else self.dlm.eps_fn(params, lengths=lengths)
            )
            out = program.sample_scan(
                eps_fn,
                x_init,
                buffers,
                self.schedule,
                cfg,
                shardings=shardings,
                lengths=lengths,
                steps=steps,
            )
            return out.x0, out.aux

        # donate x + the program's history buffers so XLA reuses them
        # in place (CPU ignores donation and would warn, so gate it);
        # args 2/3 (lengths, steps) are never donated
        nbuf = program.num_buffers(cfg)
        donate = (
            (1,) + tuple(range(4, 4 + nbuf))
            if jax.default_backend() != "cpu"
            else ()
        )
        avals = self._abstract_inputs(
            program, cfg, batch, seq_len, masked, stepped, params, shardings
        )
        # XLA exposes no per-call "came from the persistent cache" signal;
        # the hit counter moving across this compile is that signal.  Take
        # the baseline *after* lowering: tracing evaluates `timesteps`
        # grids eagerly (`ensure_compile_time_eval`), and those tiny
        # eager compiles can themselves hit the persistent cache — a
        # trace-time hit must not label the program compile "disk"
        t0 = time.perf_counter()
        lowered = jax.jit(run, donate_argnums=donate).lower(*avals)
        disk_before = disk_cache_hits()
        compiled = lowered.compile()
        wall = time.perf_counter() - t0
        source = "disk" if disk_cache_hits() > disk_before else "fresh"
        self._jitted[key] = compiled
        self._compile_counts[source] += 1
        self._m_compile_misses.inc(solver=solver, source=source)
        self._m_compile_programs.inc(solver=solver, source=source)
        self._m_compile_wall.observe(wall, solver=solver, source=source)
        return compiled, source

    def _abstract_inputs(
        self, program, cfg, batch, seq_len, masked, stepped, params, shardings
    ):
        """``ShapeDtypeStruct`` avals matching exactly what
        :meth:`_run_chunk_locked` passes the compiled program: the params
        tree (shapes only — no device traffic), the fused ``x_init``, the
        per-row ``lengths`` vector (masked buckets only, else None), the
        per-row :class:`StepMask` (stepped buckets only, else None), and
        the program's history buffers.  On a mesh every aval carries the
        same NamedSharding the run path commits its array to, so the AOT
        executable accepts those arrays without resharding."""
        d = self.dlm.config.d_model
        sds = jax.ShapeDtypeStruct
        x = sds(
            (batch, seq_len, d),
            jnp.float32,
            sharding=None if shardings is None else shardings.x,
        )
        lengths = None
        if masked:
            lengths = sds(
                (batch,),
                jnp.int32,
                sharding=None if shardings is None else shardings.lengths,
            )
        steps = None
        if stepped:
            # cfg.nfe is the bucket: the scan runs its step count, so the
            # per-row grids span steps+1 knots
            n_steps = program.steps_for_nfe(cfg.nfe, cfg)
            steps = StepMask(
                active_steps=sds(
                    (batch,),
                    jnp.int32,
                    sharding=(
                        None if shardings is None else shardings.active_steps
                    ),
                ),
                ts=sds(
                    (batch, n_steps + 1),
                    jnp.float32,
                    sharding=(
                        None if shardings is None else shardings.step_ts
                    ),
                ),
            )
        p_sharding = None if self._replicate is None else self._replicate.sharding
        p_avals = jax.tree.map(
            lambda a: sds(np.shape(a), jnp.result_type(a), sharding=p_sharding),
            params,
        )
        buffers = program.abstract_buffers(x, cfg, shardings)
        return (p_avals, x, lengths, steps, *buffers)

    # ---- ahead-of-time warmup ------------------------------------------
    def warmup(
        self,
        params,
        *,
        solvers: tuple[str, ...] | None = None,
        seq_lens: tuple[int, ...] | None = None,
        nfes: tuple[int, ...] | None = None,
        progress=None,
    ) -> dict[str, Any]:
        """Ahead-of-time compile the configured program grid — **no params
        traffic, no sampling, no drains**: every grid point is lowered from
        abstract shapes and compiled into the same ``_jitted`` cache live
        traffic reads, so the first real request of any warmed shape runs
        the solver, not the compiler.

        Grid, per solver in ``solvers`` (default: the engine's default
        solver):

        * **nfe**: the nfe-bucket ladder when this solver's traffic
          nfe-buckets (``nfe_masked``) — explicit ``nfes`` are folded onto
          their buckets, since those are the only step counts a bucketed
          stream ever compiles; otherwise ``nfes`` verbatim (default: the
          solver config's nfe).
        * **seq**: the seq-bucket ladder when this solver's traffic
          seq-buckets (``seq_masked``); otherwise traffic groups by exact
          seq_len, so the caller names the expected lengths via
          ``seq_lens`` (falling back to the ladder values as plain
          lengths, or raising when the engine has neither).
        * **batch**: the batch-bucket ladder for fusable configs;
          non-fusable configs run exact-size (their requests compile their
          own shapes at drain time), so only the smallest legal batch is
          warmed.

        Every grid point is validated through the program's own request
        policy first, so an unserveable grid (e.g. ``nfe < k`` for ERA)
        fails the boot loudly instead of compiling programs no request
        could ever use.

        ``progress`` (optional ``fn(done, total)``) and the
        ``sampler_warmup_*`` instruments report progress while compiling —
        the front door's ``/readyz`` surfaces :meth:`warmup_status`.
        Returns a report dict: grid size, per-source compile counts
        (``fresh`` / ``disk`` / ``memory``), wall seconds, and the grid
        itself.
        """
        solver_list = tuple(solvers) if solvers else (self.solver_name,)
        grid: list[tuple[str, SolverConfig, int, int, bool, bool]] = []
        seen: set[Any] = set()
        for solver in solver_list:
            program = self.program_for(solver)  # unknown solver raises
            base = self.config_for(solver)
            masked = self.seq_masked(solver)
            stepped = self.nfe_masked(solver)
            seqs = (
                self.seq_buckets
                if masked
                else (tuple(seq_lens) if seq_lens else self.seq_buckets)
            )
            if not seqs:
                raise ValueError(
                    f"warmup needs seq_lens= when the engine has no "
                    f"seq-bucket ladder (solver {solver!r} groups by exact "
                    f"seq_len)"
                )
            if self.batch_buckets and program.fusable(base):
                batches = self.batch_buckets
            else:
                # exact-size traffic: warm the smallest legal batch
                # (requests compile their own exact shapes at drain time)
                batches = (round_to_dp(1, self.mesh),)
            if stepped:
                # bucketed traffic only ever compiles the ladder's step
                # counts — fold explicit nfes onto their buckets so the
                # grid is |nfe_buckets| wide, not |nfes|
                nfe_points = (
                    tuple(sorted({self.bucket_nfe(n) for n in nfes}))
                    if nfes
                    else self.nfe_buckets
                )
            else:
                nfe_points = tuple(nfes) if nfes else (base.nfe,)
            for nfe in nfe_points:
                cfg = dataclasses.replace(base, nfe=nfe)
                for seq in seqs:
                    for b in batches:
                        # an unserveable grid point must fail the boot
                        # loudly, not compile a program no request can use
                        program.validate(
                            SampleRequest(
                                batch=b, seq_len=seq, nfe=nfe, solver=solver
                            ),
                            cfg,
                            dp=self.dp,
                        )
                        point = (solver, cfg, b, seq, masked, stepped)
                        if point not in seen:
                            seen.add(point)
                            grid.append(point)

        total = len(grid)
        counts = {"fresh": 0, "disk": 0, "memory": 0}
        t0 = time.perf_counter()
        with self._lock:
            self._warmup_state = {"state": "running", "total": total, "done": 0}
        self._m_warmup_total.set(total)
        self._m_warmup_done.set(0)
        self._m_warmup_inflight.set(1)
        done = 0
        try:
            for solver, cfg, b, seq, masked, stepped in grid:
                key = (solver, cfg, b, seq, self.dp, masked, stepped)
                with self._lock:
                    if key in self._jitted:
                        # already compiled — live traffic got there first
                        counts["memory"] += 1
                    else:
                        _, source = self._compile(key, params)
                        counts[source] += 1
                        self._m_warmup_programs.inc(solver=solver)
                    done += 1
                    self._warmup_state["done"] = done
                self._m_warmup_done.set(done)
                if progress is not None:
                    progress(done, total)
            wall = time.perf_counter() - t0
            with self._lock:
                self._warmup_state = {
                    "state": "done",
                    "total": total,
                    "done": done,
                    K.WALL_S: wall,
                    **counts,
                }
            self._m_warmup_wall.set(wall)
        except BaseException as e:
            with self._lock:
                self._warmup_state = {
                    "state": "failed",
                    "total": total,
                    "done": done,
                    "error": f"{type(e).__name__}: {e}",
                }
            raise
        finally:
            self._m_warmup_inflight.set(0)
        return {
            "programs": total,
            K.WALL_S: wall,
            "grid": [
                {"solver": s, "batch": b, "seq_len": q, "nfe": c.nfe}
                for s, c, b, q, _, _ in grid
            ],
            **counts,
        }

    def warmup_status(self) -> dict[str, Any]:
        """Warmup progress snapshot (what ``/readyz`` reports): ``state``
        none|running|done|failed plus done/total counters, and per-source
        compile counts + wall seconds once done."""
        with self._lock:
            return dict(self._warmup_state)

    # ---- introspection (tests / benchmarks) ----------------------------
    def compile_cache(self) -> dict[Any, Any]:
        """Bucket-key -> compiled executable map (each program is lowered
        and compiled exactly once, by warmup or by its first chunk)."""
        with self._lock:
            return dict(self._jitted)

    def compile_stats(self) -> dict[str, int]:
        """Program-acquisition counts by source since boot: ``fresh`` XLA
        compiles, ``disk`` persistent-cache loads, and ``memory``
        in-process executable-cache hits (one per fused chunk served)."""
        with self._lock:
            return dict(self._compile_counts)
