"""Thread-safe fused-execution core of the diffusion sampling engine.

:class:`FusedExecutor` owns everything below the request queue: request
validation, bucket selection, mesh placement, the jit cache (one compiled
program per (config, padded-batch, seq_len) bucket), chunk execution, and
per-request aux scoping.  Both entry points share one executor instance:

* the sync :class:`~repro.serving.diffusion_sampler.BatchedSampler.drain`
  path, which fuses whatever is pending at call time, and
* the continuous-batching
  :class:`~repro.serving.scheduler.AsyncBatchedSampler`, whose background
  drain thread fuses requests across arrival time.

All mutable state (jit cache, shardings cache, param replication cache) is
guarded by one re-entrant lock, and chunk execution itself is serialized
under the same lock — concurrent ``drain()`` callers and the scheduler
thread can share an executor without double-compiling a bucket or
interleaving donated-buffer executions.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import ERAConfig, NoiseSchedule, SolverConfig, get_solver
from repro.core import era as era_mod
from repro.models.diffusion import DiffusionLM
from repro.parallel.sharding import (
    ParamReplicator,
    dp_size,
    round_to_dp,
    sampler_shardings,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SampleRequest:
    batch: int
    seq_len: int
    nfe: int = 10
    solver: str = "era"
    seed: int = 0


@dataclasses.dataclass
class SampleResult:
    """Per-request output of a drained batch."""

    x0: Array                # (batch, seq_len, d_model)
    aux: dict[str, Any]      # solver diagnostics, scoped to this request's
                             # rows (per-sample histories / trajectories
                             # exclude batch-mates and pad rows)
    latency_s: float         # submit -> result wall time
    batch_wall_s: float      # wall time of the fused batch this rode in
    padded_batch: int        # bucket size the batch ran at


# A queued request: (ticket, request, submit-time).  Both the sync engine's
# pending list and the scheduler's per-shape queues carry this shape, so the
# executor can run a chunk from either source.
QueueItem = tuple[int, SampleRequest, float]


def resolve_future(fut: Future, result=None, exception=None) -> None:
    """Resolve a delivery future, tolerating client-side cancellation.

    A waiter that gave up (``fut.cancel()`` after a result() timeout) leaves
    the future in CANCELLED state; ``set_result``/``set_exception`` on it
    raises InvalidStateError, which must not take down the drain path — the
    other requests in the batch still have live waiters.
    """
    try:
        if exception is not None:
            fut.set_exception(exception)
        else:
            fut.set_result(result)
    except InvalidStateError:
        pass


class FusedExecutor:
    """Fused-chunk runner shared by the sync drain path and the scheduler."""

    def __init__(
        self,
        dlm: DiffusionLM,
        schedule: NoiseSchedule,
        solver: str = "era",
        solver_config: SolverConfig | None = None,
        batch_buckets: tuple[int, ...] | None = (1, 8, 64),
        mesh: Mesh | None = None,
    ):
        self.dlm = dlm
        self.schedule = schedule
        self.solver_name = solver
        if solver_config is None:
            # per-sample ERS isolates co-batched requests from each other
            solver_config = (
                ERAConfig(per_sample=True) if solver == "era" else SolverConfig()
            )
        self.solver_config = solver_config
        self.mesh = mesh
        self.dp = dp_size(mesh) if mesh is not None else 1
        if batch_buckets:
            # every fused batch must split evenly over the data axes, so
            # buckets round up to dp multiples (1/8/64 on dp=8 -> 8/64)
            batch_buckets = sorted({round_to_dp(b, mesh) for b in batch_buckets})
        self.batch_buckets = tuple(batch_buckets) if batch_buckets else None
        self._jitted: dict[Any, Any] = {}
        self._shardings_cache: dict[Any, Any] = {}
        self._replicate = ParamReplicator(mesh) if mesh is not None else None
        self._lock = threading.RLock()

    # ---- request policy --------------------------------------------------
    @property
    def fusable(self) -> bool:
        """Can strangers (and pad rows) share a batch under this config?

        ERA with a shared (non-per-sample) delta_eps couples every batch row
        through one global error norm — fusing strangers or adding pad rows
        would change each request's result — so such configs are served one
        exact-size request at a time instead.
        """
        return (
            not isinstance(self.solver_config, ERAConfig)
            or self.solver_config.per_sample
        )

    @property
    def max_bucket(self) -> int | None:
        return self.batch_buckets[-1] if self.batch_buckets else None

    def validate(self, req: SampleRequest) -> None:
        """Reject an invalid request at submit time, not drain time — a bad
        request must not poison the queue for its co-batched neighbours."""
        if req.batch < 1:
            raise ValueError(f"batch must be >= 1, got {req.batch}")
        k = getattr(self.solver_config, "k", None)
        if k is not None and req.nfe < k:
            raise ValueError(
                f"ERA-Solver needs nfe >= k ({req.nfe} < {k}); "
                "lower k in the engine's solver_config or raise nfe"
            )
        if not self.fusable and self.dp > 1 and req.batch % self.dp:
            # shared-delta configs run exact-size (padding would change the
            # global error norm), so a mesh drain cannot round them up to a
            # dp multiple — reject instead of silently degrading the whole
            # run to replicated placement
            raise ValueError(
                f"shared-delta (per_sample=False) ERA requests run unpadded, "
                f"so on a mesh their batch must be a multiple of the "
                f"data-parallel size ({self.dp}); got batch={req.batch}. "
                "Use a dp-multiple batch or per_sample=True."
            )

    def pack(self, items: list[QueueItem]) -> list[tuple[list[QueueItem], bool]]:
        """Split same-(seq_len, nfe) items into executable chunks.

        Fusable configs pack greedily up to the largest batch bucket;
        non-fusable configs get one exact-size (unpadded) chunk per request.
        Returns ``(chunk, pad)`` pairs.
        """
        if not self.fusable:
            return [([item], False) for item in items]
        chunks: list[tuple[list[QueueItem], bool]] = []
        chunk: list[QueueItem] = []
        total = 0
        for item in items:
            b = item[1].batch
            if chunk and self.max_bucket and total + b > self.max_bucket:
                chunks.append((chunk, True))
                chunk, total = [], 0
            chunk.append(item)
            total += b
        if chunk:
            chunks.append((chunk, True))
        return chunks

    # ---- fused execution -----------------------------------------------
    def bucket_batch(self, n: int) -> int:
        if not self.batch_buckets:
            return round_to_dp(n, self.mesh)
        for b in self.batch_buckets:
            if n <= b:
                return b
        # oversize request: exact-size compile (dp-rounded on a mesh)
        return round_to_dp(n, self.mesh)

    # ---- mesh placement ------------------------------------------------
    def _shardings(self, batch: int):
        """Carry shardings for a padded batch (None off-mesh)."""
        if self.mesh is None:
            return None
        key = batch
        if key not in self._shardings_cache:
            per_sample = (
                isinstance(self.solver_config, ERAConfig)
                and self.solver_config.per_sample
            )
            self._shardings_cache[key] = sampler_shardings(
                self.mesh, batch=batch, per_sample=per_sample
            )
        return self._shardings_cache[key]

    def run_chunk(
        self,
        params,
        seq_len: int,
        nfe: int,
        chunk: list[QueueItem],
        results: dict[int, SampleResult],
        pad: bool = True,
    ) -> None:
        """Run one chunk as a single fused program; fill ``results`` by
        ticket.  Serialized under the executor lock — safe to call from the
        scheduler thread and sync drain() callers concurrently."""
        with self._lock:
            self._run_chunk_locked(params, seq_len, nfe, chunk, results, pad)

    def _run_chunk_locked(self, params, seq_len, nfe, chunk, results, pad):
        d = self.dlm.config.d_model
        total = sum(req.batch for _, req, _ in chunk)
        padded = self.bucket_batch(total) if pad else total
        # assemble the batch on the host: eager jnp.concatenate would XLA-
        # compile once per chunk *composition* (request sizes + pad rows),
        # and under continuous batching every drain can have a new
        # composition — 40-90ms of compile against a ~10ms solver run.
        # Per-request noise stays jax.random (seed-deterministic across
        # batch compositions); numpy does the composition-shaped work.
        parts = [
            np.asarray(
                jax.random.normal(
                    jax.random.PRNGKey(req.seed),
                    (req.batch, seq_len, d),
                    jnp.float32,
                )
            )
            for _, req, _ in chunk
        ]
        if padded > total:
            parts.append(np.zeros((padded - total, seq_len, d), np.float32))
        x_init = jnp.asarray(np.concatenate(parts, axis=0))

        cfg = dataclasses.replace(self.solver_config, nfe=nfe)
        shardings = self._shardings(padded)
        if shardings is not None:
            x_init = jax.device_put(x_init, shardings.x)
            params = self._replicate(params)
        run = self._runner(cfg, padded, seq_len)
        t0 = time.perf_counter()
        if self.solver_name == "era":
            eps_buf, t_buf = era_mod.alloc_buffers(x_init, cfg, shardings)
            x0, aux = run(params, x_init, eps_buf, t_buf)
        else:
            x0, aux = run(params, x_init)
        x0 = jax.block_until_ready(x0)
        wall = time.perf_counter() - t0

        done = time.perf_counter()
        off = 0
        for ticket, req, t_submit in chunk:
            results[ticket] = SampleResult(
                x0=x0[off : off + req.batch],
                aux=self._request_aux(aux, off, req.batch),
                latency_s=done - t_submit,
                batch_wall_s=wall,
                padded_batch=padded,
            )
            off += req.batch

    @staticmethod
    def _request_aux(aux, off: int, batch: int):
        """Scope the solver diagnostics to one request's rows.

        Per-sample runs carry a (nfe, padded_batch) delta_eps history, and
        return_trajectory runs carry (nfe+1, padded_batch, ...) latents; a
        co-batched request must see only its own rows — not its batch-mates'
        (tenant isolation) and not the pad rows, which would also dilute the
        delta_eps mean."""
        per_sample = aux.get("delta_eps_history_per_sample")
        trajectory = aux.get("trajectory")
        if per_sample is None and trajectory is None:
            return aux
        scoped = dict(aux)
        if per_sample is not None:
            rows = per_sample[:, off : off + batch]
            scoped["delta_eps_history_per_sample"] = rows
            scoped["delta_eps_history"] = jnp.mean(rows, axis=-1)
        if trajectory is not None:
            scoped["trajectory"] = trajectory[:, off : off + batch]
        return scoped

    def _runner(self, cfg: SolverConfig, batch: int, seq_len: int):
        """One jitted program per (config, padded-batch, seq_len) bucket.

        Mesh-aware: the key carries the data-parallel size so an engine
        rebuilt on a different mesh never aliases a cached program."""
        key = (self.solver_name, cfg, batch, seq_len, self.dp)
        if key not in self._jitted:
            shardings = self._shardings(batch)
            if self.solver_name == "era":
                # consult the parity gate here, eagerly — the probe cannot
                # run inside the jit trace below, and this is the first ERA
                # touch on a fresh process serving only compiled buckets
                era_mod._fused_ops()

                def run(params, x_init, eps_buf, t_buf):
                    out = era_mod.sample_scan(
                        self.dlm.eps_fn(params),
                        x_init,
                        eps_buf,
                        t_buf,
                        self.schedule,
                        cfg,
                        shardings=shardings,
                    )
                    return out.x0, out.aux

                # donate x + Lagrange buffers so XLA reuses them in place
                # (CPU ignores donation and would warn, so gate it)
                donate = (1, 2, 3) if jax.default_backend() != "cpu" else ()
                self._jitted[key] = jax.jit(run, donate_argnums=donate)
            else:
                sample_fn = get_solver(self.solver_name)

                def run(params, x_init):
                    out = sample_fn(
                        self.dlm.eps_fn(params), x_init, self.schedule, cfg
                    )
                    return out.x0, out.aux

                self._jitted[key] = jax.jit(run)
        return self._jitted[key]

    # ---- introspection (tests / benchmarks) ----------------------------
    def compile_cache(self) -> dict[Any, Any]:
        """Bucket-key -> jitted runner map (each compiles exactly once)."""
        with self._lock:
            return dict(self._jitted)
