from repro.parallel.sharding import (
    SamplerShardings,
    SamplerSpecs,
    sampler_pspecs,
    sampler_shardings,
)
from repro.serving.diffusion_sampler import (
    BatchedSampler,
    SamplerService,
    fused_path_ok,
)
from repro.serving.engine import Engine, ServeConfig, cache_slots, resolve_window
from repro.serving.executor import FusedExecutor, SampleRequest, SampleResult
from repro.serving.scheduler import AsyncBatchedSampler, SchedulerPolicy, open_loop

__all__ = [
    "AsyncBatchedSampler",
    "BatchedSampler",
    "Engine",
    "FusedExecutor",
    "SampleRequest",
    "SampleResult",
    "SamplerService",
    "SamplerShardings",
    "SamplerSpecs",
    "SchedulerPolicy",
    "ServeConfig",
    "cache_slots",
    "fused_path_ok",
    "open_loop",
    "resolve_window",
    "sampler_pspecs",
    "sampler_shardings",
]
