from repro.serving.diffusion_sampler import (
    BatchedSampler,
    SampleRequest,
    SampleResult,
    SamplerService,
    fused_path_ok,
)
from repro.serving.engine import Engine, ServeConfig, cache_slots, resolve_window

__all__ = [
    "BatchedSampler",
    "Engine",
    "SampleRequest",
    "SampleResult",
    "SamplerService",
    "ServeConfig",
    "cache_slots",
    "fused_path_ok",
    "resolve_window",
]
