from repro.parallel.sharding import (
    SamplerShardings,
    SamplerSpecs,
    sampler_pspecs,
    sampler_shardings,
)
from repro.serving import result_keys
from repro.serving.compile_cache import configure_persistent_cache, disk_cache_hits
from repro.serving.diffusion_sampler import (
    BatchedSampler,
    SamplerService,
    fused_path_ok,
)
from repro.serving.engine import Engine, ServeConfig, cache_slots, resolve_window
from repro.serving.executor import (
    DEFAULT_MAX_BATCH,
    DEFAULT_MAX_NFE,
    DEFAULT_MAX_SEQ_LEN,
    SEED_MAX,
    SEED_MIN,
    FusedExecutor,
    SampleRequest,
    SampleResult,
)
from repro.serving.factory import (
    WARMUP_MODES,
    EngineConfig,
    build_engine,
    make_solver_config,
    warmup_kwargs,
)
from repro.serving.frontdoor import (
    SCHEMA_VERSION,
    FrontDoor,
    FrontDoorClient,
    SchemaError,
    decode_request,
    decode_result,
    encode_request,
    encode_result,
    serve_frontdoor,
)
from repro.serving.metrics import MetricsRegistry
from repro.serving.scheduler import (
    AsyncBatchedSampler,
    DeadlineExceededError,
    QueueFullError,
    SchedulerPolicy,
    open_loop,
)

__all__ = [
    "DEFAULT_MAX_BATCH",
    "DEFAULT_MAX_NFE",
    "DEFAULT_MAX_SEQ_LEN",
    "SCHEMA_VERSION",
    "SEED_MAX",
    "SEED_MIN",
    "AsyncBatchedSampler",
    "BatchedSampler",
    "DeadlineExceededError",
    "Engine",
    "EngineConfig",
    "FrontDoor",
    "FrontDoorClient",
    "FusedExecutor",
    "MetricsRegistry",
    "QueueFullError",
    "SampleRequest",
    "SampleResult",
    "SamplerService",
    "SamplerShardings",
    "SamplerSpecs",
    "SchedulerPolicy",
    "SchemaError",
    "ServeConfig",
    "WARMUP_MODES",
    "build_engine",
    "cache_slots",
    "configure_persistent_cache",
    "decode_request",
    "decode_result",
    "disk_cache_hits",
    "encode_request",
    "encode_result",
    "fused_path_ok",
    "make_solver_config",
    "open_loop",
    "resolve_window",
    "result_keys",
    "sampler_pspecs",
    "sampler_shardings",
    "serve_frontdoor",
    "warmup_kwargs",
]
