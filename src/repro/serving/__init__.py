from repro.parallel.sharding import (
    SamplerShardings,
    SamplerSpecs,
    sampler_pspecs,
    sampler_shardings,
)
from repro.serving.diffusion_sampler import (
    BatchedSampler,
    SampleRequest,
    SampleResult,
    SamplerService,
    fused_path_ok,
)
from repro.serving.engine import Engine, ServeConfig, cache_slots, resolve_window

__all__ = [
    "BatchedSampler",
    "Engine",
    "SampleRequest",
    "SampleResult",
    "SamplerService",
    "SamplerShardings",
    "SamplerSpecs",
    "ServeConfig",
    "cache_slots",
    "fused_path_ok",
    "resolve_window",
    "sampler_pspecs",
    "sampler_shardings",
]
