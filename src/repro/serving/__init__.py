from repro.serving.diffusion_sampler import SampleRequest, SamplerService
from repro.serving.engine import Engine, ServeConfig, cache_slots, resolve_window
