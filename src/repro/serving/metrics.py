"""Prometheus-style metrics for the serving stack (stdlib only).

A :class:`MetricsRegistry` holds named counters, gauges, and histograms;
``render()`` emits the Prometheus text exposition format that the front
door serves at ``GET /metrics``.  One registry rides with each
:class:`~repro.serving.executor.FusedExecutor`, so every layer above it —
sync drains, the continuous-batching scheduler, the HTTP front door —
instruments into the same scrape:

* executor: compile-cache hits/misses, fused-batch count/rows, fuse
  occupancy (real rows / padded rows), batch wall time;
* scheduler: per-fuse-group queue depth, admission rejects, deadline
  expirations, arrival-to-result latency histogram;
* front door: HTTP request counts by route and status code.

Thread-safety: every mutation and ``render()`` takes the instrument's (or
registry's) lock — instruments are safe to hit from the drain thread, HTTP
handler threads, and client threads concurrently.  Registration is
get-or-create: asking for an existing name returns the same instrument
(so a scheduler and a front door sharing an executor never double-register),
and asking with a different instrument type fails loudly.

This is deliberately a small, dependency-free subset of the Prometheus
client library: enough for counters/gauges/histograms with labels, the
text format, and a bucket-interpolated ``quantile()`` helper for p50/p99
readouts in benchmarks and tests.
"""

from __future__ import annotations

import math
import threading
from bisect import bisect_left

LabelKey = tuple[tuple[str, str], ...]


def _fmt(v: float) -> str:
    """Prometheus sample value: integers without a trailing .0."""
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _label_str(key: LabelKey, extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class _Metric:
    """Base: one named instrument, one value (or histogram state) per
    label set.  Labels are passed as keyword arguments to the mutators and
    stringified — ``depth.set(3, solver="era", nfe=8)``."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._values: dict[LabelKey, float] = {}

    @staticmethod
    def _key(labels: dict) -> LabelKey:
        return tuple(sorted((k, str(v)) for k, v in labels.items()))

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def _render_header(self) -> list[str]:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {_escape(self.help)}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        return lines

    def render(self) -> list[str]:
        lines = self._render_header()
        with self._lock:
            items = sorted(self._values.items())
        for key, v in items:
            lines.append(f"{self.name}{_label_str(key)} {_fmt(v)}")
        return lines


class Counter(_Metric):
    """Monotonically increasing count (``_total`` naming convention)."""

    kind = "counter"

    def inc(self, n: float = 1.0, **labels) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + n


class Gauge(_Metric):
    """A value that goes up and down (queue depth, in-flight requests)."""

    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        with self._lock:
            self._values[self._key(labels)] = float(v)

    def inc(self, n: float = 1.0, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + n

    def dec(self, n: float = 1.0, **labels) -> None:
        self.inc(-n, **labels)


#: latency-flavored default buckets (seconds), Prometheus client defaults
DEFAULT_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class Histogram(_Metric):
    """Fixed-bucket histogram: cumulative ``_bucket{le=...}`` series plus
    ``_sum`` / ``_count``, and a bucket-interpolated :meth:`quantile` for
    in-process p50/p99 readouts (benchmarks, tests — a real deployment
    computes quantiles scrape-side)."""

    kind = "histogram"

    def __init__(self, name, help="", buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(buckets))
        # per label set: [per-bucket counts..., +Inf count], sum
        self._counts: dict[LabelKey, list[int]] = {}
        self._sums: dict[LabelKey, float] = {}

    def observe(self, v: float, **labels) -> None:
        key = self._key(labels)
        i = bisect_left(self.buckets, v)
        with self._lock:
            counts = self._counts.get(key)
            if counts is None:
                counts = self._counts[key] = [0] * (len(self.buckets) + 1)
                self._sums[key] = 0.0
            counts[i] += 1
            self._sums[key] += v

    def count(self, **labels) -> int:
        with self._lock:
            return sum(self._counts.get(self._key(labels), ()))

    def sum(self, **labels) -> float:
        with self._lock:
            return self._sums.get(self._key(labels), 0.0)

    def quantile(self, q: float, **labels) -> float:
        """Linear-interpolated quantile from the cumulative buckets (the
        same estimate Prometheus' ``histogram_quantile`` computes).  NaN
        with no observations; the largest finite bound when the quantile
        lands in the +Inf bucket."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            counts = list(self._counts.get(self._key(labels), ()))
        total = sum(counts)
        if total == 0:
            return math.nan
        rank = q * total
        seen = 0.0
        for i, c in enumerate(counts):
            if seen + c >= rank and c > 0:
                if i >= len(self.buckets):  # +Inf bucket
                    return self.buckets[-1]
                lo = 0.0 if i == 0 else self.buckets[i - 1]
                hi = self.buckets[i]
                return lo + (hi - lo) * max(0.0, rank - seen) / c
            seen += c
        return self.buckets[-1]

    def render(self) -> list[str]:
        lines = self._render_header()
        with self._lock:
            items = sorted(
                (k, list(c), self._sums[k]) for k, c in self._counts.items()
            )
        for key, counts, total in items:
            cum = 0
            for bound, c in zip(self.buckets, counts):
                cum += c
                le = _label_str(key, f'le="{_fmt(bound)}"')
                lines.append(f"{self.name}_bucket{le} {cum}")
            cum += counts[-1]
            le = _label_str(key, 'le="+Inf"')
            lines.append(f"{self.name}_bucket{le} {cum}")
            lines.append(f"{self.name}_sum{_label_str(key)} {_fmt(total)}")
            lines.append(f"{self.name}_count{_label_str(key)} {cum}")
        return lines


class MetricsRegistry:
    """Named instruments + the text exposition the front door scrapes."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _get_or_create(self, cls, name, help, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(
        self, name: str, help: str = "",
        buckets: tuple[float, ...] = DEFAULT_BUCKETS,
    ) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> _Metric | None:
        with self._lock:
            return self._metrics.get(name)

    def render(self) -> str:
        """The full Prometheus text exposition (``text/plain; version=0.0.4``)."""
        with self._lock:
            metrics = sorted(self._metrics.items())
        lines: list[str] = []
        for _, m in metrics:
            lines.extend(m.render())
        return "\n".join(lines) + "\n"
