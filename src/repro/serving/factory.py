"""One engine-construction path for every serve mode.

``launch/serve.py`` grew three ways to stand up a sampling engine (facade,
continuous scheduler, and now the HTTP front door), each hand-assembling
solver configs and bucket ladders.  This module is the single factory they
all go through: an :class:`EngineConfig` captures every engine-shape
decision as one frozen, hashable value, and :func:`build_engine` turns it
into a :class:`~repro.serving.diffusion_sampler.BatchedSampler`.  The HTTP
server, the ``--continuous`` simulator, and the one-shot facade therefore
serve *the same engine* — same solver config, same fuse buckets, same
compile-cache shape — so a result observed over the wire is the result the
in-process paths produce.
"""

from __future__ import annotations

import dataclasses

from repro.core import (
    ERAConfig,
    NoiseSchedule,
    SolverConfig,
    default_config,
)
from repro.models.diffusion import DiffusionLM
from repro.serving.diffusion_sampler import BatchedSampler
from repro.serving.executor import (
    DEFAULT_MAX_BATCH,
    DEFAULT_MAX_NFE,
    DEFAULT_MAX_SEQ_LEN,
)
from repro.serving.metrics import MetricsRegistry


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything that shapes a serving engine, in one frozen value.

    * ``solver`` / ``nfe`` — the default solver program and its step count
      (per-request ``SampleRequest.solver`` routing still works on top).
    * ``k`` / ``lam`` — ERA Lagrange order and error-robust selection
      weight (ignored by non-ERA solvers, which take their registry
      defaults at this ``nfe``).
    * ``per_sample`` — per-sample ERS (the serving default: keeps every
      row of a fused batch independent).  ``False`` = the paper's shared
      scalar delta_eps, which couples a batch, so the engine serves such
      configs one exact-size request at a time.
    * ``batch_buckets`` — compiled batch-shape ladder (``None`` =
      exact-size, no fusion — the facade's shape).
    * ``seq_buckets`` — opt-in mixed-seq-len fusion ladder (``None`` =
      exact seq_len per fuse group).
    * ``max_batch`` / ``max_nfe`` / ``max_seq_len`` — per-request resource
      ceilings enforced at submit (HTTP 400 at the front door): a single
      wire request must not be able to force a multi-GB allocation or a
      pathological compile after admission.  ``None`` = unbounded
      (trusted in-process callers); ``max_seq_len`` applies only when no
      ``seq_buckets`` ladder already bounds the sequence axis.
    """

    solver: str = "era"
    nfe: int = 10
    k: int = 4
    lam: float = 5.0
    per_sample: bool = True
    batch_buckets: tuple[int, ...] | None = (1, 8, 64)
    seq_buckets: tuple[int, ...] | None = None
    max_batch: int | None = DEFAULT_MAX_BATCH
    max_nfe: int | None = DEFAULT_MAX_NFE
    max_seq_len: int | None = DEFAULT_MAX_SEQ_LEN


def make_solver_config(cfg: EngineConfig) -> SolverConfig:
    """The default-solver config an :class:`EngineConfig` implies: a full
    :class:`~repro.core.ERAConfig` for ``era``, the registry default at
    ``cfg.nfe`` for everything else."""
    if cfg.solver == "era":
        return ERAConfig(
            nfe=cfg.nfe, k=cfg.k, lam=cfg.lam, per_sample=cfg.per_sample
        )
    return default_config(cfg.solver, nfe=cfg.nfe)


def build_engine(
    dlm: DiffusionLM,
    schedule: NoiseSchedule,
    cfg: EngineConfig | None = None,
    mesh=None,
    metrics: MetricsRegistry | None = None,
) -> BatchedSampler:
    """Construct the engine every serve mode shares.

    ``mesh`` and ``metrics`` are runtime resources, not engine shape, so
    they ride alongside the config rather than inside it (a mesh is not
    hashable; a registry is per-process state)."""
    cfg = cfg if cfg is not None else EngineConfig()
    return BatchedSampler(
        dlm,
        schedule,
        cfg.solver,
        make_solver_config(cfg),
        batch_buckets=cfg.batch_buckets,
        mesh=mesh,
        seq_buckets=cfg.seq_buckets,
        metrics=metrics,
        max_batch=cfg.max_batch,
        max_nfe=cfg.max_nfe,
        max_seq_len=cfg.max_seq_len,
    )
