"""One engine-construction path for every serve mode.

``launch/serve.py`` grew three ways to stand up a sampling engine (facade,
continuous scheduler, and now the HTTP front door), each hand-assembling
solver configs and bucket ladders.  This module is the single factory they
all go through: an :class:`EngineConfig` captures every engine-shape
decision as one frozen, hashable value, and :func:`build_engine` turns it
into a :class:`~repro.serving.diffusion_sampler.BatchedSampler`.  The HTTP
server, the ``--continuous`` simulator, and the one-shot facade therefore
serve *the same engine* — same solver config, same fuse buckets, same
compile-cache shape — so a result observed over the wire is the result the
in-process paths produce.
"""

from __future__ import annotations

import dataclasses

from repro.core import (
    ERAConfig,
    NoiseSchedule,
    SolverConfig,
    default_config,
)
from repro.models.diffusion import DiffusionLM
from repro.serving.compile_cache import configure_persistent_cache
from repro.serving.diffusion_sampler import BatchedSampler
from repro.serving.executor import (
    DEFAULT_MAX_BATCH,
    DEFAULT_MAX_NFE,
    DEFAULT_MAX_SEQ_LEN,
)
from repro.serving.metrics import MetricsRegistry

#: legal values of :attr:`EngineConfig.warmup`
WARMUP_MODES = ("none", "grid")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything that shapes a serving engine, in one frozen value.

    * ``solver`` / ``nfe`` — the default solver program and its step count
      (per-request ``SampleRequest.solver`` routing still works on top).
    * ``k`` / ``lam`` — ERA Lagrange order and error-robust selection
      weight (ignored by non-ERA solvers, which take their registry
      defaults at this ``nfe``).
    * ``per_sample`` — per-sample ERS (the serving default: keeps every
      row of a fused batch independent).  ``False`` = the paper's shared
      scalar delta_eps, which couples a batch, so the engine serves such
      configs one exact-size request at a time.
    * ``batch_buckets`` — compiled batch-shape ladder (``None`` =
      exact-size, no fusion — the facade's shape).
    * ``seq_buckets`` — opt-in mixed-seq-len fusion ladder (``None`` =
      exact seq_len per fuse group).
    * ``nfe_buckets`` — opt-in mixed-NFE fusion ladder (``None`` = exact
      nfe per fuse group): requests whose ``nfe`` differ share one
      compiled program that scans to the bucketed max step count under
      per-row step masks, and the warmup grid / jit cache are bounded by
      the ladder instead of by distinct request NFEs.  Requests above the
      top bucket are rejected at submit, like the seq ladder.
    * ``max_batch`` / ``max_nfe`` / ``max_seq_len`` — per-request resource
      ceilings enforced at submit (HTTP 400 at the front door): a single
      wire request must not be able to force a multi-GB allocation or a
      pathological compile after admission.  ``None`` = unbounded
      (trusted in-process callers); ``max_seq_len`` applies only when no
      ``seq_buckets`` ladder already bounds the sequence axis.
    * ``warmup`` — cold-start policy: ``"grid"`` = callers should AOT
      pre-compile the configured program grid at boot
      (:meth:`~repro.serving.diffusion_sampler.BatchedSampler.warmup` with
      :func:`warmup_kwargs`); ``"none"`` = programs compile lazily at
      first request.  ``warmup_nfes`` / ``warmup_seq_lens`` extend the
      grid beyond the defaults (the config's ``nfe``; the seq-bucket
      ladder, or — for exact-seq-len traffic — the lengths callers expect
      to serve).
    * ``compile_cache_dir`` — persistent XLA compilation cache directory
      (``jax_compilation_cache_dir``, process-global): a redeployed
      replica's warmup becomes disk loads instead of fresh compiles.  The
      ``compile_cache_*`` thresholds mirror the ``jax_persistent_cache_*``
      flags but default to persisting everything — see
      :func:`~repro.serving.compile_cache.configure_persistent_cache`.
    """

    solver: str = "era"
    nfe: int = 10
    k: int = 4
    lam: float = 5.0
    per_sample: bool = True
    batch_buckets: tuple[int, ...] | None = (1, 8, 64)
    seq_buckets: tuple[int, ...] | None = None
    nfe_buckets: tuple[int, ...] | None = None
    max_batch: int | None = DEFAULT_MAX_BATCH
    max_nfe: int | None = DEFAULT_MAX_NFE
    max_seq_len: int | None = DEFAULT_MAX_SEQ_LEN
    warmup: str = "none"
    warmup_nfes: tuple[int, ...] | None = None
    warmup_seq_lens: tuple[int, ...] | None = None
    compile_cache_dir: str | None = None
    compile_cache_min_entry_bytes: int = -1
    compile_cache_min_compile_secs: float = 0.0


def make_solver_config(cfg: EngineConfig) -> SolverConfig:
    """The default-solver config an :class:`EngineConfig` implies: a full
    :class:`~repro.core.ERAConfig` for ``era``, the registry default at
    ``cfg.nfe`` for everything else."""
    if cfg.solver == "era":
        return ERAConfig(
            nfe=cfg.nfe, k=cfg.k, lam=cfg.lam, per_sample=cfg.per_sample
        )
    return default_config(cfg.solver, nfe=cfg.nfe)


def build_engine(
    dlm: DiffusionLM,
    schedule: NoiseSchedule,
    cfg: EngineConfig | None = None,
    mesh=None,
    metrics: MetricsRegistry | None = None,
) -> BatchedSampler:
    """Construct the engine every serve mode shares.

    ``mesh`` and ``metrics`` are runtime resources, not engine shape, so
    they ride alongside the config rather than inside it (a mesh is not
    hashable; a registry is per-process state).

    ``cfg.compile_cache_dir`` is applied here (process-global jax config);
    ``cfg.warmup`` is *policy*, not an action — building an engine never
    compiles.  Callers run the warmup themselves once params are in hand:
    ``engine.warmup(params, **warmup_kwargs(cfg))`` (or hand the kwargs to
    :func:`~repro.serving.frontdoor.serve_frontdoor`, which runs it on a
    background thread behind ``/readyz``)."""
    cfg = cfg if cfg is not None else EngineConfig()
    if cfg.warmup not in WARMUP_MODES:
        raise ValueError(
            f"EngineConfig.warmup must be one of {WARMUP_MODES}, "
            f"got {cfg.warmup!r}"
        )
    if cfg.compile_cache_dir:
        configure_persistent_cache(
            cfg.compile_cache_dir,
            min_entry_size_bytes=cfg.compile_cache_min_entry_bytes,
            min_compile_time_secs=cfg.compile_cache_min_compile_secs,
        )
    return BatchedSampler(
        dlm,
        schedule,
        cfg.solver,
        make_solver_config(cfg),
        batch_buckets=cfg.batch_buckets,
        mesh=mesh,
        seq_buckets=cfg.seq_buckets,
        nfe_buckets=cfg.nfe_buckets,
        metrics=metrics,
        max_batch=cfg.max_batch,
        max_nfe=cfg.max_nfe,
        max_seq_len=cfg.max_seq_len,
    )


def warmup_kwargs(cfg: EngineConfig) -> dict | None:
    """The ``warmup(...)`` keyword set an :class:`EngineConfig` implies —
    ``None`` when ``cfg.warmup == "none"`` (don't warm).  Callers with
    params in hand do::

        kw = warmup_kwargs(cfg)
        if kw is not None:
            engine.warmup(params, **kw)
    """
    if cfg.warmup == "none":
        return None
    # with an nfe-bucket ladder the grid's step counts ARE the ladder
    # (explicit warmup_nfes still fold onto their buckets in the executor);
    # without one, traffic groups by exact nfe, so warm the config's
    default_nfes = None if cfg.nfe_buckets else (cfg.nfe,)
    return {
        "nfes": cfg.warmup_nfes or default_nfes,
        "seq_lens": cfg.warmup_seq_lens,
    }
