"""AdamW + LR schedules, implemented directly on pytrees (no optax dep).

State is a pytree mirroring params: {"m": ..., "v": ..., "step": scalar}.
Supports bf16 params with f32 optimizer state (the production layout the
dry-run memory analysis accounts for).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | linear | constant


def lr_at(cfg: OptimizerConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        if cfg.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - frac
    return cfg.lr * warm * decay


def init_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(params) -> dict:
    z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(z, params),
        "v": jax.tree.map(z, params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def apply_updates(
    cfg: OptimizerConfig, params, grads, state
) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    b1, b2 = cfg.betas
    lr = lr_at(cfg, step)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
