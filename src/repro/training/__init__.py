from repro.training.optimizer import OptimizerConfig, apply_updates, init_state
from repro.training.train_loop import (
    make_diffusion_train_step,
    make_lm_train_step,
    train,
)
