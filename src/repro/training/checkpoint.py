"""Checkpointing: flat .npz archives keyed by pytree paths.

No orbax in the container; this covers save/restore of params + optimizer
state + step with atomic writes and a retention policy.  Arrays are pulled
to host; restore rebuilds the exact pytree structure from the key paths.
"""

from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def _set_path(tree: dict, parts: list[str], value):
    cur = tree
    for part in parts[:-1]:
        cur = cur.setdefault(part, {})
    cur[parts[-1]] = value


def save(path: str, tree: Any, step: int | None = None) -> str:
    """Atomically write `tree` to `<path>` (.npz)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __meta__=json.dumps({"step": step}), **flat)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for t in (tmp, tmp + ".npz"):
            if os.path.exists(t):
                os.remove(t)
    return path


def restore(path: str) -> tuple[dict, int | None]:
    """Load a checkpoint into a nested-dict pytree. Returns (tree, step)."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"])) if "__meta__" in z else {}
        tree: dict = {}
        for key in z.files:
            if key == "__meta__":
                continue
            _set_path(tree, key.split("/"), z[key])
    return tree, meta.get("step")


def latest(ckpt_dir: str, prefix: str = "ckpt_") -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    best, best_step = None, -1
    for f in os.listdir(ckpt_dir):
        m = re.fullmatch(rf"{re.escape(prefix)}(\d+)\.npz", f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(ckpt_dir, f), int(m.group(1))
    return best


def save_rotating(
    ckpt_dir: str, tree: Any, step: int, keep: int = 3, prefix: str = "ckpt_"
) -> str:
    path = os.path.join(ckpt_dir, f"{prefix}{step:08d}.npz")
    save(path, tree, step)
    stale = sorted(
        f
        for f in os.listdir(ckpt_dir)
        if re.fullmatch(rf"{re.escape(prefix)}\d+\.npz", f)
    )[:-keep]
    for f in stale:
        os.remove(os.path.join(ckpt_dir, f))
    return path
