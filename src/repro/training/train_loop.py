"""Training loop: step builders (LM and diffusion-LM) + the host loop.

``make_train_step`` returns a pure (params, opt_state, batch, rng) ->
(params, opt_state, metrics) function suitable for jit/pjit with explicit
shardings — the same function the multi-pod dry-run lowers.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.core.schedules import NoiseSchedule
from repro.models.diffusion import DiffusionLM
from repro.models.model import Model
from repro.parallel.ctx import constrain_batch
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt

Array = jax.Array


def make_lm_train_step(
    model: Model, opt_cfg: opt.OptimizerConfig, microbatches: int = 1
) -> Callable:
    """LM train step; ``microbatches > 1`` adds gradient accumulation
    (lax.scan over batch slices) so long-sequence activations fit HBM."""

    def grads_of(params, batch):
        def loss_fn(p):
            return model.loss(p, batch)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def step(params, opt_state, batch, rng):
        del rng
        if microbatches <= 1:
            (loss, aux), grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]),
                batch,
            )

            def body(acc, sl):
                sl = jax.tree.map(constrain_batch, sl)
                (l, a), g = grads_of(params, sl)
                acc = (
                    acc[0] + l,
                    jax.tree.map(jnp.add, acc[1], a),
                    jax.tree.map(jnp.add, acc[2], g),
                )
                return acc, None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            zero_a = {
                "xent": jnp.float32(0.0),
                "moe_aux": jnp.float32(0.0),
                "moe_z": jnp.float32(0.0),
            }
            (loss, aux, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zero_a, zero_g), mb
            )
            inv = 1.0 / microbatches
            loss = loss * inv
            aux = jax.tree.map(lambda x: x * inv, aux)
            grads = jax.tree.map(lambda g: g * inv, grads)
        params, opt_state, om = opt.apply_updates(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **aux, **om}
        return params, opt_state, metrics

    return step


def make_diffusion_train_step(
    dlm: DiffusionLM, opt_cfg: opt.OptimizerConfig, schedule: NoiseSchedule
) -> Callable:
    def step(params, opt_state, batch, rng):
        def loss_fn(p):
            return dlm.loss(p, batch, rng, schedule)

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, om = opt.apply_updates(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **aux, **om}

    return step


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    history: list[dict]


def train(
    step_fn: Callable,
    params,
    batches: Iterator[dict],
    num_steps: int,
    *,
    seed: int = 0,
    log_every: int = 10,
    ckpt_dir: str | None = None,
    ckpt_every: int = 200,
    to_device: Callable[[dict], dict] = lambda b: b,
    print_fn: Callable[[str], None] = print,
) -> TrainResult:
    """Host loop: jit the step, feed batches, log, checkpoint."""
    opt_state = opt.init_state(params)
    step_jit = jax.jit(step_fn, donate_argnums=(0, 1))
    key = jax.random.PRNGKey(seed)
    history = []
    t0 = time.perf_counter()
    for i in range(num_steps):
        batch = to_device(next(batches))
        key, sub = jax.random.split(key)
        params, opt_state, metrics = step_jit(params, opt_state, batch, sub)
        if i % log_every == 0 or i == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall_s"] = round(time.perf_counter() - t0, 2)
            history.append(m)
            print_fn(
                f"step {i:5d} loss {m.get('loss', float('nan')):.4f} "
                f"lr {m.get('lr', 0):.2e} ({m['wall_s']:.1f}s)"
            )
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            ckpt.save_rotating(
                ckpt_dir, {"params": params, "opt": opt_state}, i + 1
            )
    if ckpt_dir:
        ckpt.save_rotating(
            ckpt_dir, {"params": params, "opt": opt_state}, num_steps
        )
    return TrainResult(params=params, opt_state=opt_state, history=history)
