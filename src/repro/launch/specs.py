"""Abstract input builders for the dry-run: ShapeDtypeStruct stand-ins for
every (architecture x input shape) entry point — weak-type-correct,
shardable, zero allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.registry import InputShape, long_context_policy
from repro.models.model import Model
from repro.training import optimizer as opt

Sds = jax.ShapeDtypeStruct


def _extras_abstract(cfg: ModelConfig, batch: int) -> dict:
    out = {}
    if cfg.family == "vlm":
        out["patches"] = Sds(
            (batch, cfg.frontend.num_positions, cfg.d_model), cfg.dtype
        )
    if cfg.family == "audio":
        out["frames"] = Sds(
            (batch, cfg.frontend.num_positions, cfg.d_model), cfg.dtype
        )
    return out


def decode_slots(cfg: ModelConfig, shape: InputShape) -> int:
    """Cache slots for a decode shape (ring buffer for windowed archs)."""
    if shape.seq_len > 65536:  # long_500k
        if long_context_policy(cfg) == "swa":
            return cfg.long_context_window + cfg.num_meta_tokens
        if cfg.sliding_window:
            return cfg.sliding_window + cfg.num_meta_tokens
        # SSM-only stacks still create (tiny) attention caches in hybrid
        return (cfg.sliding_window or 4096) + cfg.num_meta_tokens
    return shape.seq_len + cfg.num_meta_tokens


def decode_window_override(cfg: ModelConfig, shape: InputShape) -> int:
    if shape.seq_len > 65536 and long_context_policy(cfg) == "swa":
        return cfg.long_context_window
    return -1


@dataclasses.dataclass
class Program:
    """A lowerable entry point: fn(*args) with abstract args."""

    name: str
    fn: Callable
    args: tuple
    donate: tuple = ()


def train_microbatches(
    cfg: ModelConfig, shape: InputShape, dp: int = 16,
    act_budget: float = 3e9,
) -> int:
    """Gradient-accumulation factor so remat-saved layer inputs
    (L x B_dev/mu x S x d x 2B) fit the activation budget; mu is a power of
    two capped at one sample per device per microbatch (B/dp)."""
    b_dev = max(shape.global_batch // dp, 1)
    acts = cfg.num_layers * b_dev * shape.seq_len * cfg.d_model * 2
    mu = 1
    while acts / mu > act_budget and mu < b_dev:
        mu *= 2
    return mu


def build_program(model: Model, shape: InputShape, dp: int = 16) -> Program:
    """The entry point a given input shape exercises."""
    cfg = model.config
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        batch = {"tokens": Sds((b, s), jnp.int32), **_extras_abstract(cfg, b)}
        aparams = model.init_abstract()
        aopt = opt.abstract_state(aparams)
        rng = Sds((2,), jnp.uint32)

        from repro.training.train_loop import make_lm_train_step

        mb = train_microbatches(cfg, shape, dp)
        step = make_lm_train_step(model, opt.OptimizerConfig(), microbatches=mb)
        return Program("train_step", step, (aparams, aopt, batch, rng))

    if shape.kind == "prefill":
        batch = {"tokens": Sds((b, s), jnp.int32), **_extras_abstract(cfg, b)}
        aparams = model.init_abstract(jnp.bfloat16)   # serving weights
        slots = s + cfg.num_meta_tokens

        def prefill(params, batch):
            return model.prefill(params, batch, slots)

        return Program("prefill_step", prefill, (aparams, batch))

    # decode
    slots = decode_slots(cfg, shape)
    wo = decode_window_override(cfg, shape)
    batch = {
        "tokens": Sds((b, 1), jnp.int32),
        "pos": Sds((), jnp.int32),
    }
    aparams = model.init_abstract(jnp.bfloat16)       # serving weights
    acache = model.abstract_cache(b, slots)

    def decode(params, cache, batch):
        return model.decode(params, cache, batch, window_override=wo)

    return Program("decode_step", decode, (aparams, acache, batch), donate=(1,))
