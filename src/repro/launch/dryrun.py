import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, record memory/cost/collective analysis.

MUST be run as its own process (the device-count flag above is set before
any other import, including jax).  One combo per invocation keeps compile
memory bounded; ``--all`` orchestrates subprocesses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import INPUT_SHAPES, arch_names, get_config  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_program  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.parallel.ctx import activation_sharding  # noqa: E402
from repro.parallel.sharding import ShardingRules, data_axes  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")


def shardings_for(program, rules: ShardingRules):
    """in_shardings matching each program's argument tuple."""
    if program.name == "train_step":
        aparams, aopt, batch, rng = program.args
        return (
            rules.param_sharding(aparams),
            rules.opt_sharding(aopt),
            rules.batch_sharding(batch),
            rules.replicated(),
        )
    if program.name == "prefill_step":
        aparams, batch = program.args
        return (rules.param_sharding(aparams), rules.batch_sharding(batch))
    aparams, acache, batch = program.args
    return (
        rules.param_sharding(aparams),
        rules.cache_sharding(acache),
        rules.batch_sharding(batch),
    )


def run_one(arch: str, shape_name: str, mesh_kind: str, out_dir: str) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    program = build_program(model, shape, dp=(32 if mesh_kind == "multi" else 16))
    rules = ShardingRules(cfg, mesh, fsdp=(program.name == "train_step"))
    in_sh = shardings_for(program, rules)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "entry": program.name,
        "num_devices": mesh.devices.size,
        "ok": False,
    }
    t0 = time.perf_counter()
    with mesh, activation_sharding(data_axes(mesh)):
        jitted = jax.jit(program.fn, in_shardings=in_sh)
        lowered = jitted.lower(*program.args)
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 2)

    try:
        ca = compiled.cost_analysis()
        rec["cost_analysis"] = {
            k: float(v)
            for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                "flops" in k.lower() or "bytes" in k.lower() or "utilization" not in k.lower()
            )
        }
        rec["flops_per_device"] = float(ca.get("flops", 0.0))
        rec["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = repr(e)

    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            )
            if hasattr(ma, k)
        }
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = repr(e)

    try:
        text = compiled.as_text()
        rec["hlo"] = hlo_analysis.analyze(text)   # loop-aware flops/bytes/collectives
        rec["hlo_chars"] = len(text)
    except Exception as e:  # pragma: no cover
        rec["collective_error"] = repr(e)

    rec["ok"] = True
    rec["total_s"] = round(time.perf_counter() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_all(out_dir: str, meshes=("single", "multi"), resume=True) -> None:
    combos = [
        (a, s, m)
        for a in arch_names()
        for s in INPUT_SHAPES
        for m in meshes
    ]
    for arch, shape, mesh_kind in combos:
        path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")
        if resume and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("ok"):
                    print(f"skip {arch} {shape} {mesh_kind} (done)")
                    continue
        print(f"=== {arch} {shape} {mesh_kind}", flush=True)
        t0 = time.perf_counter()
        proc = subprocess.run(
            [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                "--out", out_dir,
            ],
            env={**os.environ},
            capture_output=True,
            text=True,
            timeout=3600,
        )
        dt = time.perf_counter() - t0
        if proc.returncode != 0:
            print(f"FAIL ({dt:.0f}s):\n{proc.stdout[-2000:]}\n{proc.stderr[-4000:]}")
            os.makedirs(out_dir, exist_ok=True)
            with open(path, "w") as f:
                json.dump(
                    {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "ok": False, "error": proc.stderr[-4000:],
                    },
                    f, indent=1,
                )
        else:
            print(f"ok ({dt:.0f}s)")


def run_solver_program(
    arch: str, mesh_kind: str, out_dir: str,
    solver: str = "era", nfe: int = 10, batch: int = 32, seq: int = 2048,
    bf16_buffer: bool = False,
) -> dict:
    """Lower the paper's full sampling loop (Algorithm 1) as one program —
    the §Perf target-C artifact."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import ERAConfig, SolverConfig, linear_schedule
    from repro.models.diffusion import DiffusionLM
    from repro.serving import SamplerService

    cfg = get_config(arch).with_(param_dtype=jnp.bfloat16)
    dlm = DiffusionLM(build_model(cfg))
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = ShardingRules(cfg, mesh)
    aparams = dlm.init_abstract()
    rep = lambda t: jax.tree.map(lambda _: rules.replicated(), t)
    psh = {
        "backbone": rules.param_sharding(aparams["backbone"]),
        "time_mlp": rep(aparams["time_mlp"]),
        "in_proj": rep(aparams["in_proj"]),
        "eps_head": rep(aparams["eps_head"]),
    }
    x = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.float32)
    xsh = NamedSharding(mesh, P(data_axes(mesh), None, None))
    if solver == "era":
        sc = ERAConfig(
            nfe=nfe, k=4,
            solver_dtype=jnp.bfloat16 if bf16_buffer else jnp.float32,
        )
    else:
        sc = SolverConfig(nfe=nfe)
    svc = SamplerService(dlm, linear_schedule(), solver, sc)

    rec = {
        "arch": arch, "mesh": mesh_kind, "entry": f"sample_{solver}",
        "solver": solver, "nfe": nfe, "batch": batch, "seq": seq,
        "bf16_buffer": bf16_buffer, "num_devices": mesh.devices.size,
        "ok": False,
    }
    t0 = time.perf_counter()
    with mesh, activation_sharding(data_axes(mesh)):
        compiled = (
            jax.jit(svc.sample_program(), in_shardings=(psh, xsh))
            .lower(aparams, x)
            .compile()
        )
    rec["compile_s"] = round(time.perf_counter() - t0, 2)
    rec["hlo"] = hlo_analysis.analyze(compiled.as_text())
    ma = compiled.memory_analysis()
    rec["memory_analysis"] = {
        "argument_size_in_bytes": int(ma.argument_size_in_bytes),
        "temp_size_in_bytes": int(ma.temp_size_in_bytes),
    }
    rec["ok"] = True
    os.makedirs(out_dir, exist_ok=True)
    suffix = "bf16" if bf16_buffer else "f32"
    path = os.path.join(out_dir, f"solver__{arch}__{solver}_{suffix}__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out", default=os.path.normpath(OUT_DIR))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument(
        "--solver-program", action="store_true",
        help="lower the full ERA/DDIM sampling loop instead of an input shape",
    )
    ap.add_argument("--solver", default="era")
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--bf16-buffer", action="store_true")
    args = ap.parse_args()

    if args.all:
        run_all(args.out, resume=not args.no_resume)
        return
    if args.solver_program:
        rec = run_solver_program(
            args.arch or "qwen2-1.5b", args.mesh, args.out,
            solver=args.solver, nfe=args.nfe, bf16_buffer=args.bf16_buffer,
        )
        print(json.dumps(rec, indent=1))
        return
    assert args.arch and args.shape, "--arch and --shape required"
    rec = run_one(args.arch, args.shape, args.mesh, args.out)
    drop = {"cost_analysis"}
    print(json.dumps({k: v for k, v in rec.items() if k not in drop}, indent=1))


if __name__ == "__main__":
    main()
