"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 50 --batch 8 --seq 128

On real hardware the same entry point runs the production mesh; on CPU the
host mesh is (device_count, 1).  ``--diffusion`` trains the diffusion-LM
denoiser (the paper's setting) instead of the AR objective.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import arch_names, get_config
from repro.core import linear_schedule
from repro.data import DataConfig, frontend_features, make_loader
from repro.models import build_model
from repro.models.diffusion import DiffusionLM
from repro.training import (
    OptimizerConfig,
    make_diffusion_train_step,
    make_lm_train_step,
    train,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=arch_names())
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--diffusion", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    opt_cfg = OptimizerConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 5), total_steps=args.steps
    )
    rng = np.random.default_rng(args.seed)

    if args.diffusion:
        dlm = DiffusionLM(model)
        params = dlm.init(key)
        sched = linear_schedule()
        dc = DataConfig(
            vocab_size=1, seq_len=args.seq, batch_size=args.batch,
            kind="diffusion", d_model=cfg.d_model, seed=args.seed,
        )
        loader = make_loader(dc).batches()
        step = make_diffusion_train_step(dlm, opt_cfg, sched)
        n_params = sum(x.size for x in jax.tree.leaves(params))
    else:
        params = model.init(key)
        dc = DataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            batch_size=args.batch, seed=args.seed,
        )
        base = make_loader(dc).batches()

        def with_extras():
            for b in base:
                if cfg.family == "vlm":
                    b["patches"] = frontend_features(
                        rng, args.batch, cfg.frontend.num_positions, cfg.d_model
                    )
                if cfg.family == "audio":
                    b["frames"] = frontend_features(
                        rng, args.batch, cfg.frontend.num_positions, cfg.d_model
                    )
                yield b

        loader = with_extras()
        step = make_lm_train_step(model, opt_cfg)
        n_params = model.param_count()

    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")
    res = train(
        step, params, loader, args.steps,
        seed=args.seed, ckpt_dir=args.ckpt_dir,
    )
    print(f"final loss: {res.history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
