"""Serving launcher: AR generation or ERA-Solver diffusion sampling.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --mode ar --batch 4 --prompt-len 16 --gen 32
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --mode diffusion --solver era --nfe 10
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --mode diffusion --continuous --requests 16 --rate 20
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --mode diffusion --listen --port 0
    PYTHONPATH=src python -m repro.launch.serve \
        --mode diffusion --connect http://127.0.0.1:8752 --requests 4

``--continuous`` drives the continuous-batching scheduler with a simulated
open-loop client: ``--requests`` single-sample requests arrive with Poisson
gaps at ``--rate`` req/s (open-loop — arrivals never wait for service), and
the run reports p50/p99 arrival-to-result latency, throughput, and how full
the fused batches ran.

``--listen`` runs the HTTP front door (``POST /v1/sample``, ``GET
/metrics``, ``GET /healthz`` liveness, ``GET /readyz`` readiness — see
docs/serving.md) over the same engine and scheduler; once the socket is
bound it prints the machine-parsable ready line ``FRONTDOOR READY <url>``
(``--port 0`` binds an ephemeral port) and serves until interrupted.  The
AOT warmup grid compiles on a background thread behind ``/readyz``
(``--no-warm`` to skip; ``--compile-cache-dir`` turns redeploy warmups
into disk loads).  ``--connect URL`` is the matching wire client: it
needs no model or params, just the server's URL.

Every diffusion mode builds its engine through
:func:`repro.serving.build_engine` — the one-shot facade, the continuous
simulator, and the HTTP server run the same construction path, so a
result observed over the wire is the result the in-process paths produce.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import arch_names, get_config
from repro.core import linear_schedule, solver_names
from repro.data import frontend_features
from repro.models import build_model
from repro.models.diffusion import DiffusionLM
from repro.serving import (
    AsyncBatchedSampler,
    Engine,
    EngineConfig,
    FrontDoorClient,
    SampleRequest,
    SamplerService,
    SchedulerPolicy,
    ServeConfig,
    build_engine,
    open_loop,
    result_keys as K,
    serve_frontdoor,
    warmup_kwargs,
)


def _engine_config(
    args, per_sample: bool, fused: bool,
    warmup_seq_lens: tuple[int, ...] | None = None,
) -> EngineConfig:
    """CLI args -> the one EngineConfig every diffusion mode builds from.
    ``fused`` engines get the serving bucket ladder; the one-shot facade
    runs exact-size (no fusion).  ``warmup_seq_lens`` names the exact
    lengths the AOT warmup grid covers when the engine has no seq-bucket
    ladder (each mode passes the lengths its traffic will use)."""
    seq_buckets = (
        tuple(int(x) for x in args.seq_buckets.split(","))
        if args.seq_buckets
        else None
    )
    nfe_buckets = (
        tuple(int(x) for x in args.nfe_buckets.split(","))
        if args.nfe_buckets
        else None
    )
    batch_buckets = tuple(int(x) for x in args.batch_buckets.split(","))
    return EngineConfig(
        solver=args.solver,
        nfe=args.nfe,
        k=args.k,
        lam=args.lam,
        per_sample=per_sample,
        batch_buckets=batch_buckets if fused else None,
        seq_buckets=seq_buckets if fused else None,
        nfe_buckets=nfe_buckets if fused else None,
        warmup="grid" if (fused and args.warm) else "none",
        warmup_nfes=(
            tuple(int(x) for x in args.warmup_nfes.split(","))
            if args.warmup_nfes
            else None
        ),
        warmup_seq_lens=warmup_seq_lens if fused else None,
        compile_cache_dir=args.compile_cache_dir,
    )


def _warm_engine(engine, params, cfg: EngineConfig, mix) -> None:
    """AOT-compile the engine's program grid for every solver in ``mix``
    (no sampling — abstract shapes only; see ``BatchedSampler.warmup``)."""
    kw = warmup_kwargs(cfg)
    if kw is None:
        return
    rep = engine.warmup(params, solvers=tuple(mix), **kw)
    print(
        f"warmup: {rep['programs']} programs in {rep['wall_s']:.2f}s "
        f"({rep['fresh']} fresh, {rep['disk']} from compile cache)",
        flush=True,
    )


def run_continuous(dlm, params, args) -> None:
    """Open-loop Poisson client against the continuous-batching scheduler.

    With ``--mix solver_a,solver_b,...`` the stream cycles requests through
    several registry solvers — each request routes to its own solver's
    program inside one engine (per-(solver, seq, nfe) fuse queues).  With
    ``--seq-buckets`` + ``--seq-mix-lens``, requests of different lengths
    fuse into shared length-masked batches; with ``--nfe-buckets`` +
    ``--nfe-mix-nfes``, requests of different step budgets fuse into
    shared step-masked batches (see docs/serving.md)."""
    mix = [s.strip() for s in args.mix.split(",")] if args.mix else [args.solver]
    lens = (
        [int(x) for x in args.seq_mix_lens.split(",")]
        if args.seq_mix_lens
        else [args.seq]
    )
    nfes = (
        [int(x) for x in args.nfe_mix_nfes.split(",")]
        if args.nfe_mix_nfes
        else [args.nfe]
    )
    cfg = _engine_config(
        args, per_sample=True, fused=True, warmup_seq_lens=tuple(lens)
    )
    engine = build_engine(dlm, linear_schedule(), cfg)
    _warm_engine(engine, params, cfg, mix)

    policy = SchedulerPolicy(
        max_wait_ms=args.max_wait_ms, target_occupancy=args.occupancy
    )
    rng = np.random.default_rng(args.seed)
    gaps = rng.exponential(1.0 / args.rate, args.requests)
    futures = []
    with AsyncBatchedSampler(engine, params, policy) as sched:
        t_start = open_loop(
            gaps,
            lambda i: futures.append(
                sched.submit(
                    SampleRequest(
                        batch=1, seq_len=lens[i % len(lens)],
                        nfe=nfes[i % len(nfes)],
                        solver=mix[i % len(mix)], seed=args.seed + i,
                    )
                )
            ),
        )
        results = [f.result() for f in futures]
        makespan = time.perf_counter() - t_start
        stats = sched.stats()
    lats_ms = np.array([r.latency_s for r in results]) * 1e3
    print(
        f"continuous[{','.join(mix)}]: {args.requests} req @ {args.rate:.1f}/s "
        f"(max_wait={policy.max_wait_ms}ms occ={policy.target_occupancy}) | "
        f"p50={np.percentile(lats_ms, 50):.1f}ms "
        f"p99={np.percentile(lats_ms, 99):.1f}ms "
        f"thpt={args.requests / makespan:.1f}/s "
        f"batches={stats[K.BATCHES]} "
        f"mean_rows={stats[K.MEAN_BATCH_ROWS]:.1f}"
    )


def run_listen(dlm, params, args) -> None:
    """HTTP front-door server: bind, print the ready line, serve until
    interrupted.  The AOT warmup grid (default solver × batch buckets ×
    seq buckets × nfe) compiles on a background thread — the listener is
    up immediately, and ``GET /readyz`` flips 503 -> 200 once the grid is
    in (``--no-warm`` skips it: ready at bind, first requests compile)."""
    cfg = _engine_config(
        args, per_sample=True, fused=True, warmup_seq_lens=(args.seq,)
    )
    engine = build_engine(dlm, linear_schedule(), cfg)
    policy = SchedulerPolicy(
        max_wait_ms=args.max_wait_ms,
        target_occupancy=args.occupancy,
        max_queue_rows=(
            args.max_queue_rows if args.max_queue_rows > 0 else None
        ),
    )
    kw = warmup_kwargs(cfg)
    door = serve_frontdoor(
        engine, params, policy, host=args.host, port=args.port,
        warmup=(
            {**kw, "solvers": (args.solver,)} if kw is not None else None
        ),
    )
    # machine-parsable sentinel: bench_serving and tests wait for this
    # line before opening the client (bind != ready — poll /readyz for
    # the end of the compile wall)
    print(f"FRONTDOOR READY {door.url}", flush=True)
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        door.stop()


def run_connect(args) -> None:
    """Wire client: sample over HTTP against a running ``--listen``
    server.  Needs no local model — the request is pure schema."""
    client = FrontDoorClient(args.connect, timeout=args.timeout)
    lats_ms = []
    for i in range(args.requests):
        t0 = time.perf_counter()
        res = client.sample(
            SampleRequest(
                batch=args.batch, seq_len=args.seq, nfe=args.nfe,
                solver=args.solver, seed=args.seed + i,
            )
        )
        lats_ms.append((time.perf_counter() - t0) * 1e3)
        x0 = res.x0
        print(
            f"req[{i}] x0 {x0.shape} via {args.solver} nfe={args.nfe} | "
            f"wire={lats_ms[-1]:.1f}ms engine_wall={res.info[K.WALL_S]:.2f}s "
            f"(mean {float(np.mean(x0)):+.4f}, std {float(np.std(x0)):.4f})"
        )
    print(
        f"connect: {args.requests} req | "
        f"p50={np.percentile(lats_ms, 50):.1f}ms "
        f"p99={np.percentile(lats_ms, 99):.1f}ms"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=arch_names())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", choices=["ar", "diffusion"], default="ar")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--window", type=int, default=-1)
    ap.add_argument("--solver", default="era", choices=solver_names())
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--lam", type=float, default=5.0)
    ap.add_argument("--seq", type=int, default=32, help="diffusion seq len")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--continuous",
        action="store_true",
        help="serve a simulated open-loop Poisson stream through the "
        "continuous-batching scheduler (diffusion mode only)",
    )
    ap.add_argument(
        "--listen",
        action="store_true",
        help="run the HTTP front door over the continuous-batching "
        "scheduler (diffusion mode only); prints 'FRONTDOOR READY <url>' "
        "once bound",
    )
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument(
        "--port", type=int, default=0,
        help="--listen port (0 = ephemeral, reported in the ready line)",
    )
    ap.add_argument(
        "--connect",
        default=None,
        metavar="URL",
        help="act as a wire client against a running --listen server "
        "(diffusion mode only; no local model needed)",
    )
    ap.add_argument(
        "--timeout", type=float, default=None,
        help="--connect per-request socket timeout in seconds",
    )
    ap.add_argument(
        "--max-queue-rows", type=int, default=4096,
        help="--listen admission bound per fuse-group queue (HTTP 429 "
        "past it; default 4096, <= 0 for unbounded)",
    )
    ap.add_argument(
        "--no-warm", dest="warm", action="store_false",
        help="skip the AOT warmup grid compile (--listen boots ready "
        "immediately; first requests pay their own compiles)",
    )
    ap.add_argument(
        "--warmup-nfes",
        default=None,
        help="comma-separated NFE list the AOT warmup grid covers "
        "(default: --nfe only)",
    )
    ap.add_argument(
        "--compile-cache-dir",
        default=None,
        metavar="DIR",
        help="persistent XLA compilation cache directory "
        "(jax_compilation_cache_dir): warmup on a redeployed replica "
        "loads yesterday's programs from disk instead of recompiling",
    )
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument(
        "--mix",
        default=None,
        help="comma-separated registry solvers to cycle the --continuous "
        "stream through (per-request routing in one engine), e.g. "
        "'era,ddim,dpm_solver_pp2m'",
    )
    ap.add_argument("--rate", type=float, default=20.0, help="arrivals/s")
    ap.add_argument(
        "--batch-buckets",
        default="1,8,64",
        help="comma-separated batch-shape ladder for the fused "
        "(--continuous/--listen) engine",
    )
    ap.add_argument(
        "--seq-buckets",
        default=None,
        help="comma-separated seq-bucket ladder for the --continuous "
        "engine (mixed-seq-len fusion with padding masks), e.g. '32,64'",
    )
    ap.add_argument(
        "--seq-mix-lens",
        default=None,
        help="comma-separated seq_lens the --continuous stream cycles "
        "through (default: --seq only)",
    )
    ap.add_argument(
        "--nfe-buckets",
        default=None,
        help="comma-separated NFE-bucket ladder for the fused "
        "(--continuous/--listen) engine (mixed-NFE fusion with per-row "
        "step masks; requests above the top bucket are rejected), e.g. "
        "'12,25'",
    )
    ap.add_argument(
        "--nfe-mix-nfes",
        default=None,
        help="comma-separated NFE budgets the --continuous stream cycles "
        "through (default: --nfe only)",
    )
    ap.add_argument("--max-wait-ms", type=float, default=25.0)
    ap.add_argument(
        "--occupancy", type=float, default=1.0,
        help="launch a batch early once this fraction of the largest "
        "bucket is pending",
    )
    args = ap.parse_args()
    if (args.continuous or args.listen or args.connect) and args.mode != "diffusion":
        ap.error("--continuous/--listen/--connect require --mode diffusion")
    if args.connect:
        run_connect(args)
        return

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)

    if args.mode == "diffusion":
        dlm = DiffusionLM(model)
        params = dlm.init(key)
        if args.listen:
            run_listen(dlm, params, args)
            return
        if args.continuous:
            run_continuous(dlm, params, args)
            return
        svc = SamplerService(
            engine=build_engine(
                dlm,
                linear_schedule(),
                _engine_config(args, per_sample=False, fused=False),
            )
        )
        req = SampleRequest(
            batch=args.batch, seq_len=args.seq, nfe=args.nfe, seed=args.seed
        )
        res = svc.sample(params, req)
        x0 = res.x0
        print(
            f"sampled latents {x0.shape} via {args.solver} nfe={args.nfe} "
            f"in {res.info[K.WALL_S]:.2f}s "
            f"(mean {float(jnp.mean(x0)):+.4f}, std {float(jnp.std(x0)):.4f})"
        )
        return

    params = model.init(key)
    eng = Engine(model, ServeConfig(max_len=args.max_len, window_override=args.window))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = jnp.asarray(
            frontend_features(rng, args.batch, cfg.frontend.num_positions, cfg.d_model)
        )
    if cfg.family == "audio":
        extras["frames"] = jnp.asarray(
            frontend_features(rng, args.batch, cfg.frontend.num_positions, cfg.d_model)
        )
    t0 = time.perf_counter()
    toks = eng.generate(params, prompts, args.gen, extras=extras, key=key)
    toks = jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(
        f"generated {toks.shape} in {dt:.2f}s "
        f"({args.batch * args.gen / dt:.1f} tok/s); first row: "
        f"{toks[0][:10].tolist()}"
    )


if __name__ == "__main__":
    main()
