"""Serving launcher: AR generation or ERA-Solver diffusion sampling.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --mode ar --batch 4 --prompt-len 16 --gen 32
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --mode diffusion --solver era --nfe 10
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --mode diffusion --continuous --requests 16 --rate 20

``--continuous`` drives the continuous-batching scheduler with a simulated
open-loop client: ``--requests`` single-sample requests arrive with Poisson
gaps at ``--rate`` req/s (open-loop — arrivals never wait for service), and
the run reports p50/p99 arrival-to-result latency, throughput, and how full
the fused batches ran.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import arch_names, get_config
from repro.core import ERAConfig, default_config, linear_schedule, solver_names
from repro.data import frontend_features
from repro.models import build_model
from repro.models.diffusion import DiffusionLM
from repro.serving import (
    AsyncBatchedSampler,
    BatchedSampler,
    Engine,
    SampleRequest,
    SamplerService,
    SchedulerPolicy,
    ServeConfig,
    open_loop,
)


def _solver_config(args, per_sample: bool = False):
    if args.solver == "era":
        return ERAConfig(
            nfe=args.nfe, k=args.k, lam=args.lam, per_sample=per_sample
        )
    return default_config(args.solver, nfe=args.nfe)


def run_continuous(dlm, params, args) -> None:
    """Open-loop Poisson client against the continuous-batching scheduler.

    With ``--mix solver_a,solver_b,...`` the stream cycles requests through
    several registry solvers — each request routes to its own solver's
    program inside one engine (per-(solver, seq, nfe) fuse queues).  With
    ``--seq-buckets`` + ``--seq-mix-lens``, requests of different lengths
    fuse into shared length-masked batches (see docs/serving.md)."""
    mix = [s.strip() for s in args.mix.split(",")] if args.mix else [args.solver]
    seq_buckets = (
        tuple(int(x) for x in args.seq_buckets.split(","))
        if args.seq_buckets
        else None
    )
    lens = (
        [int(x) for x in args.seq_mix_lens.split(",")]
        if args.seq_mix_lens
        else [args.seq]
    )
    engine = BatchedSampler(
        dlm,
        linear_schedule(),
        args.solver,
        _solver_config(args, per_sample=True),
        batch_buckets=(1, 8, 64),
        seq_buckets=seq_buckets,
    )
    # compile every (solver, batch bucket, seq group) program before the
    # timed stream — one warmup drain per distinct seq group so lone
    # requests at any length hit a warm program
    seq_groups = sorted({engine.executor.group_key(
        SampleRequest(batch=1, seq_len=ln, nfe=args.nfe)
    )[1] for ln in lens})
    for solver in mix:
        for bucket in engine.batch_buckets:
            for seq in seq_groups:
                for i in range(bucket):
                    engine.submit(
                        SampleRequest(
                            batch=1, seq_len=seq, nfe=args.nfe,
                            solver=solver, seed=10_000 + i,
                        )
                    )
                engine.drain(params)

    policy = SchedulerPolicy(
        max_wait_ms=args.max_wait_ms, target_occupancy=args.occupancy
    )
    rng = np.random.default_rng(args.seed)
    gaps = rng.exponential(1.0 / args.rate, args.requests)
    futures = []
    with AsyncBatchedSampler(engine, params, policy) as sched:
        t_start = open_loop(
            gaps,
            lambda i: futures.append(
                sched.submit(
                    SampleRequest(
                        batch=1, seq_len=lens[i % len(lens)], nfe=args.nfe,
                        solver=mix[i % len(mix)], seed=args.seed + i,
                    )
                )
            ),
        )
        results = [f.result() for f in futures]
        makespan = time.perf_counter() - t_start
        stats = sched.stats()
    lats_ms = np.array([r.latency_s for r in results]) * 1e3
    print(
        f"continuous[{','.join(mix)}]: {args.requests} req @ {args.rate:.1f}/s "
        f"(max_wait={policy.max_wait_ms}ms occ={policy.target_occupancy}) | "
        f"p50={np.percentile(lats_ms, 50):.1f}ms "
        f"p99={np.percentile(lats_ms, 99):.1f}ms "
        f"thpt={args.requests / makespan:.1f}/s "
        f"batches={stats['batches']} "
        f"mean_rows={stats['mean_batch_rows']:.1f}"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=arch_names())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", choices=["ar", "diffusion"], default="ar")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--window", type=int, default=-1)
    ap.add_argument("--solver", default="era", choices=solver_names())
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--lam", type=float, default=5.0)
    ap.add_argument("--seq", type=int, default=32, help="diffusion seq len")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--continuous",
        action="store_true",
        help="serve a simulated open-loop Poisson stream through the "
        "continuous-batching scheduler (diffusion mode only)",
    )
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument(
        "--mix",
        default=None,
        help="comma-separated registry solvers to cycle the --continuous "
        "stream through (per-request routing in one engine), e.g. "
        "'era,ddim,dpm_solver_pp2m'",
    )
    ap.add_argument("--rate", type=float, default=20.0, help="arrivals/s")
    ap.add_argument(
        "--seq-buckets",
        default=None,
        help="comma-separated seq-bucket ladder for the --continuous "
        "engine (mixed-seq-len fusion with padding masks), e.g. '32,64'",
    )
    ap.add_argument(
        "--seq-mix-lens",
        default=None,
        help="comma-separated seq_lens the --continuous stream cycles "
        "through (default: --seq only)",
    )
    ap.add_argument("--max-wait-ms", type=float, default=25.0)
    ap.add_argument(
        "--occupancy", type=float, default=1.0,
        help="launch a batch early once this fraction of the largest "
        "bucket is pending",
    )
    args = ap.parse_args()
    if args.continuous and args.mode != "diffusion":
        ap.error("--continuous requires --mode diffusion")

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)

    if args.mode == "diffusion":
        dlm = DiffusionLM(model)
        params = dlm.init(key)
        if args.continuous:
            run_continuous(dlm, params, args)
            return
        svc = SamplerService(
            dlm, linear_schedule(), args.solver, _solver_config(args)
        )
        req = SampleRequest(
            batch=args.batch, seq_len=args.seq, nfe=args.nfe, seed=args.seed
        )
        x0, info = svc.sample(params, req)
        print(
            f"sampled latents {x0.shape} via {args.solver} nfe={args.nfe} "
            f"in {info['wall_s']:.2f}s "
            f"(mean {float(jnp.mean(x0)):+.4f}, std {float(jnp.std(x0)):.4f})"
        )
        return

    params = model.init(key)
    eng = Engine(model, ServeConfig(max_len=args.max_len, window_override=args.window))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = jnp.asarray(
            frontend_features(rng, args.batch, cfg.frontend.num_positions, cfg.d_model)
        )
    if cfg.family == "audio":
        extras["frames"] = jnp.asarray(
            frontend_features(rng, args.batch, cfg.frontend.num_positions, cfg.d_model)
        )
    t0 = time.perf_counter()
    toks = eng.generate(params, prompts, args.gen, extras=extras, key=key)
    toks = jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(
        f"generated {toks.shape} in {dt:.2f}s "
        f"({args.batch * args.gen / dt:.1f} tok/s); first row: "
        f"{toks[0][:10].tolist()}"
    )


if __name__ == "__main__":
    main()
