"""Loop-aware HLO analysis for the roofline terms.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — under
scan-over-layers (every model here) that understates FLOPs/bytes by the
layer count, and it has no collective term at all.  This module parses the
compiled (post-SPMD, per-device) HLO text into a computation call graph,
recovers loop trip counts from the loop-condition constants, and accumulates

  * flops            — 2 * prod(result dims) * prod(contracting dims) per
                       ``dot``, wherever it lives (fusions included)
  * hbm_bytes        — operands + results of top-level ops (fusions counted
                       as atomic; tuple/GTE/bitcast/param/const free)
  * collectives[k]   — result bytes per collective kind

multiplied along while-loop nesting.  Trip count = max integer constant in
the loop condition computation (XLA emits ``compare(counter, constant(N))``)
— exact for lax.scan/fori_loop-generated loops.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "iota", "after-all", "partition-id", "replica-id",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\("
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALL_ATTR_RE = re.compile(r"(to_apply|calls|body|condition)=\{?%?([\w\.\-]+)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COMP_HDR_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*(\(.*\))\s*->\s*.*\{\s*$"
)
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*(\w+)\[([\d,]*)\]")


def _shape_bytes_of_type(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(m.group(1), 4)
    return total


def _first_shape(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, ()
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict[str, str]  # instr/param name -> type str


def parse_computations(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    depth = 0
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), [], {})
                depth = 1
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                if m.group(2):
                    for pm in _PARAM_RE.finditer(m.group(2)):
                        cur.shapes[pm.group(1)] = f"{pm.group(2)}[{pm.group(3)}]"
            continue
        depth += line.count("{") - line.count("}")
        line = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=N*/ comments
        im = _INSTR_RE.match(line)
        if im:
            name, type_str, op = im.group(1), im.group(2), im.group(3)
            cur.instrs.append(Instr(name, type_str, op, line))
            cur.shapes[name] = type_str
        if depth <= 0:
            comps[cur.name] = cur
            cur = None
    return comps, entry


def _dot_flops(instr: Instr, comp: Computation) -> float:
    _, rdims = _first_shape(instr.type_str)
    n = 1.0
    for d in rdims:
        n *= d
    cm = _LHS_CDIMS_RE.search(instr.line)
    k = 1.0
    if cm:
        # lhs operand = first %name inside the parens after the op
        paren = instr.line.split(instr.op + "(", 1)[-1]
        om = _OPERAND_RE.search(paren)
        if om and om.group(1) in comp.shapes:
            _, ldims = _first_shape(comp.shapes[om.group(1)])
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(ldims):
                    k *= ldims[int(idx)]
    return 2.0 * n * k


def _instr_hbm_bytes(instr: Instr, comp: Computation) -> float:
    if instr.op in _FREE_OPS or instr.op == "while":
        return 0.0
    total = float(_shape_bytes_of_type(instr.type_str))
    paren = instr.line.split(instr.op + "(", 1)[-1]
    # cut trailing attributes to avoid matching computation names
    paren = paren.split("), ")[0]
    for om in _OPERAND_RE.finditer(paren):
        t = comp.shapes.get(om.group(1))
        if t:
            total += _shape_bytes_of_type(t)
    return total


@dataclasses.dataclass
class Stats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    dot_bytes: float = 0.0      # operand+result traffic of dots only
    collectives: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Stats", mult: float = 1.0):
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        self.dot_bytes += mult * other.dot_bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + mult * v


def _trip_count(comps, cond_name: str | None) -> int:
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for instr in comp.instrs:
        for m in _CONST_RE.finditer(instr.line):
            best = max(best, int(m.group(1)))
    return best


def analyze(text: str) -> dict:
    comps, entry = parse_computations(text)
    memo: dict[tuple[str, bool], Stats] = {}

    def walk(name: str, count_bytes: bool, seen: frozenset) -> Stats:
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        if comp is None or name in seen:
            return Stats()
        seen = seen | {name}
        st = Stats()
        for instr in comp.instrs:
            if instr.op == "dot":
                st.flops += _dot_flops(instr, comp)
                st.dot_bytes += _instr_hbm_bytes(instr, comp)
            if instr.op in COLLECTIVES or any(
                instr.op.startswith(c) for c in COLLECTIVES
            ):
                kind = next(c for c in COLLECTIVES if instr.op.startswith(c))
                b = float(_shape_bytes_of_type(instr.type_str))
                st.collectives[kind] = st.collectives.get(kind, 0.0) + b
            if count_bytes:
                st.hbm_bytes += _instr_hbm_bytes(instr, comp)
            if instr.op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", instr.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", instr.line)
                if bm:
                    trips = _trip_count(comps, cm.group(1) if cm else None)
                    st.add(walk(bm.group(1), count_bytes, seen), trips)
            elif instr.op == "fusion":
                fm = re.search(r"calls=%?([\w\.\-]+)", instr.line)
                if fm:  # flops inside fusions count; bytes are atomic
                    st.add(walk(fm.group(1), False, seen), 1.0)
            elif instr.op == "conditional":
                bm = re.search(r"branch_computations=\{([^}]*)\}", instr.line)
                branches = []
                if bm:
                    branches = [
                        b.strip().lstrip("%") for b in bm.group(1).split(",")
                    ]
                else:
                    branches = [
                        m2.group(1)
                        for m2 in re.finditer(
                            r"(?:true|false)_computation=%?([\w\.\-]+)",
                            instr.line,
                        )
                    ]
                if branches:
                    # conservative: cost of the most expensive branch
                    cand = [walk(bn, count_bytes, seen) for bn in branches]
                    best = max(
                        cand,
                        key=lambda s_: (s_.flops, s_.hbm_bytes,
                                        sum(s_.collectives.values())),
                    )
                    st.add(best, 1.0)
            elif instr.op in ("call", "async-start"):
                for _, callee in _CALL_ATTR_RE.findall(instr.line):
                    st.add(walk(callee, count_bytes, seen), 1.0)
        memo[key] = st
        return st

    if entry is None:
        return {"error": "no ENTRY computation found"}
    st = walk(entry, True, frozenset())
    return {
        "flops": st.flops,
        "hbm_bytes": st.hbm_bytes,          # unfused upper bound (CPU HLO)
        "dot_bytes": st.dot_bytes,          # matmul operand/result traffic
        "collectives": st.collectives,
        "collective_bytes_total": sum(st.collectives.values()),
    }


def collective_bytes(text: str) -> dict:
    """Back-compat shim: collective byte totals only."""
    res = analyze(text)
    out = dict(res.get("collectives", {}))
    return out
