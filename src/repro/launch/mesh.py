"""Production mesh factory (TPU v5e).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — only the dry-run sets the 512-placeholder-
device XLA flag, and only in its own process.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever fits the local devices (CPU smoke tests / examples)."""
    n = jax.device_count()
    dp = n // model_parallel
    return jax.make_mesh((dp, model_parallel), ("data", "model"))


def make_sampler_mesh(max_devices: int | None = None):
    """Data-only mesh for the batched sampling engine.

    The sampler shards only the batch dimension (params replicate, per-
    sample ERS stays shard-local), so a single "data" axis over the local
    devices is the whole topology.  ``max_devices`` caps the axis for tests
    that want a fixed dp on machines with more devices."""
    n = jax.device_count()
    if max_devices is not None:
        n = min(n, max_devices)
    return jax.make_mesh((n,), ("data",), devices=jax.devices()[:n])


# TPU v5e hardware constants for the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link
