"""Model assembly: config -> parameter specs + train/prefill/decode closures.

A :class:`Model` bundles everything the substrate layers need:

    m = build_model(cfg)
    params = m.init(key)                       # real weights
    aparams = m.init_abstract()                # ShapeDtypeStructs (dry-run)
    logits, aux = m.forward(params, batch)     # teacher forcing
    loss, aux = m.loss(params, batch)
    logits, cache = m.prefill(params, batch, slots)
    logits, cache = m.decode(params, cache, tokens, pos)

Layer stacks are homogeneous segments scanned with ``lax.scan`` over stacked
parameters (compile-time is O(#segments), not O(#layers) — 95-layer
DeepSeek-67B compiles as one scan).  Heterogeneous architectures (xLSTM's
mLSTM/sLSTM interleave, Hymba's full/SWA mix) are tuples of segments.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.blocks import BLOCKS, BlockCtx
from repro.parallel.ctx import constrain_batch

Array = jax.Array


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def model_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    specs: dict[str, Any] = {
        "embed": L.P((cfg.padded_vocab, d), "embed"),
        "final_norm": (
            L.layernorm_specs(d) if cfg.family == "audio" else L.rmsnorm_specs(d)
        ),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = L.P((d, cfg.padded_vocab), "fan_in")
    if cfg.num_meta_tokens:
        specs["meta"] = L.P((cfg.num_meta_tokens, d), "embed")
    if cfg.family == "audio":
        # learned decoder positions (Whisper)
        specs["pos_embed"] = L.P((cfg.max_position, d), "embed")
        enc_seg = L.stack_specs(BLOCKS["enc"].specs(cfg), cfg.num_encoder_layers)
        specs["encoder"] = {
            "segs": {"0_enc": enc_seg},
            "norm": L.layernorm_specs(d),
        }
    segs = {}
    for i, (kind, count) in enumerate(cfg.blocks):
        segs[f"{i}_{kind}"] = L.stack_specs(BLOCKS[kind].specs(cfg), count)
    specs["segs"] = segs
    return specs


# ---------------------------------------------------------------------------
# stack execution
# ---------------------------------------------------------------------------


def _run_segment(
    kind: str,
    seg_params,
    x: Array,
    seg_cache,
    ctx: BlockCtx,
    cfg: ModelConfig,
):
    """Scan one homogeneous segment. seg_cache has leading (L,) or None."""
    block = BLOCKS[kind]

    def body(x, xs):
        p, c = xs
        x, new_c, aux = block.apply(p, x, c, ctx, cfg)
        return x, (new_c, aux)

    if cfg.remat and ctx.mode == "train":
        body = jax.checkpoint(body)

    if seg_cache is None:
        x, (_, aux) = jax.lax.scan(body, x, (seg_params, None))
    else:
        x, (new_cache, aux) = jax.lax.scan(body, x, (seg_params, seg_cache))
        return x, new_cache, aux
    return x, None, aux


def _stack(params, x, cache, ctx: BlockCtx, cfg: ModelConfig):
    new_cache = {}
    auxes = []
    x = constrain_batch(x)
    for i, (kind, count) in enumerate(cfg.blocks):
        key = f"{i}_{kind}"
        seg_cache = None if cache is None else cache[key]
        x, nc, aux = _run_segment(kind, params["segs"][key], x, seg_cache, ctx, cfg)
        x = constrain_batch(x)
        if cache is not None:
            new_cache[key] = nc
        auxes.append(jax.tree.map(jnp.sum, aux))
    aux = jax.tree.map(lambda *xs: sum(xs), *auxes)
    return x, (new_cache if cache is not None else None), aux


def _encode(params, frontend: Array, cfg: ModelConfig) -> Array:
    """Whisper encoder over stub frame embeddings (B, F, d)."""
    f = frontend.shape[1]
    pos = L.sinusoidal_time_embed(
        jnp.arange(f, dtype=jnp.float32) / 1000.0, cfg.d_model
    )
    x = frontend.astype(cfg.dtype) + pos.astype(cfg.dtype)
    ctx = BlockCtx(mode="train")
    x, _, _ = _run_segment(
        "enc", params["encoder"]["segs"]["0_enc"], x, None, ctx, cfg
    )
    return L.layernorm(params["encoder"]["norm"], x, cfg.norm_eps)


def _embed_tokens(params, tokens: Array, cfg: ModelConfig) -> Array:
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.family == "vlm":  # gemma scales embeddings
        h = h * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    return h


def _prefix_embeds(params, batch: dict, cfg: ModelConfig):
    """Per-family sequence prefix (meta tokens / image patches)."""
    parts = []
    if cfg.num_meta_tokens:
        b = batch["tokens"].shape[0]
        parts.append(
            jnp.broadcast_to(
                params["meta"].astype(cfg.dtype),
                (b, cfg.num_meta_tokens, cfg.d_model),
            )
        )
    if cfg.family == "vlm":
        parts.append(batch["patches"].astype(cfg.dtype))
    return parts


def _lm_logits(params, h: Array, cfg: ModelConfig) -> Array:
    w = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(h.dtype)
    return h @ w


def _positions_offset(batch: dict, cfg: ModelConfig) -> int:
    off = cfg.num_meta_tokens
    if cfg.family == "vlm":
        off += batch["patches"].shape[1]
    return off


# ---------------------------------------------------------------------------
# the Model bundle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Model:
    config: ModelConfig

    # ---- params ----
    def specs(self) -> dict:
        return model_specs(self.config)

    def init(self, key: jax.Array) -> dict:
        return L.init_params(self.specs(), key, self.config.param_dtype)

    def init_abstract(self, dtype=None) -> dict:
        return L.abstract_params(self.specs(), dtype or self.config.param_dtype)

    def param_count(self) -> int:
        return L.count_params(self.specs())

    # ---- caches ----
    def _slots_for(self, kind: str, slots: int) -> int:
        """Sliding-window blocks only need ring buffers of window size."""
        cfg = self.config
        if kind in ("hymba_swa",) or (
            kind in ("dense", "moe") and cfg.sliding_window > 0
        ):
            return min(slots, cfg.sliding_window + cfg.num_meta_tokens)
        return slots

    def _cache(self, batch_size: int, slots: int, abstract: bool):
        cfg = self.config
        out = {}
        for i, (kind, count) in enumerate(cfg.blocks):
            block = BLOCKS[kind]
            if block.cache is None:
                continue
            one = block.cache(
                cfg, batch_size, self._slots_for(kind, slots), cfg.dtype, abstract
            )
            if abstract:
                out[f"{i}_{kind}"] = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((count,) + s.shape, s.dtype), one
                )
            else:
                out[f"{i}_{kind}"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (count,) + x.shape).copy(),
                    one,
                )
        return out

    def init_cache(self, batch_size: int, slots: int) -> dict:
        return self._cache(batch_size, slots, abstract=False)

    def abstract_cache(self, batch_size: int, slots: int) -> dict:
        return self._cache(batch_size, slots, abstract=True)

    # ---- forward passes ----
    def _assemble(
        self, params, batch, mode: str, cache=None, causal=True,
        window_override: int = -1,
    ):
        cfg = self.config
        tokens = batch["tokens"]
        h_tok = _embed_tokens(params, tokens, cfg)
        # prefix (meta tokens / image patches) only enters at train/prefill;
        # during decode it already lives in the cache
        prefix = [] if mode == "decode" else _prefix_embeds(params, batch, cfg)
        h = jnp.concatenate(prefix + [h_tok], axis=1) if prefix else h_tok

        enc_out = None
        if cfg.family == "audio":
            if mode != "decode":
                enc_out = _encode(params, batch["frames"], cfg)
            if mode == "decode":
                pe = jax.lax.dynamic_index_in_dim(
                    params["pos_embed"], batch["pos"], 0, keepdims=True
                )
                h = h + pe[None].astype(h.dtype)          # (B,1,d)+(1,1,d)
            else:
                s = h.shape[1]
                h = h + params["pos_embed"][:s].astype(h.dtype)

        ctx = BlockCtx(
            mode=mode,
            pos=batch.get("pos"),
            causal=causal,
            window_override=window_override,
            protected=cfg.num_meta_tokens,
            enc_out=enc_out,
        )
        h, cache, aux = _stack(params, h, cache, ctx, cfg)
        norm = (
            L.layernorm if cfg.family == "audio" else L.rmsnorm
        )
        h = norm(params["final_norm"], h, cfg.norm_eps)
        return h, cache, aux

    def forward(self, params, batch: dict) -> tuple[Array, dict]:
        """Teacher-forcing full-sequence logits (train mode)."""
        h, _, aux = self._assemble(params, batch, "train")
        return _lm_logits(params, h, self.config), aux

    def loss(self, params, batch: dict) -> tuple[Array, dict]:
        cfg = self.config
        logits, aux = self.forward(params, batch)
        off = _positions_offset(batch, cfg)
        logits = logits[:, off:, :]
        tokens = batch["tokens"]
        tgt = tokens[:, 1:]
        lg = logits[:, :-1, :].astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        mask = (
            jnp.ones_like(tgt, jnp.float32) if mask is None else mask[:, 1:]
        )
        xent = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = xent
        if cfg.moe is not None:
            total = (
                total
                + cfg.moe.aux_loss_weight * aux["moe_aux"]
                + cfg.moe.router_z_loss * aux["moe_z"]
            )
        return total, {"xent": xent, **aux}

    def prefill(
        self, params, batch: dict, slots: int, window_override: int = -1
    ) -> tuple[Array, dict]:
        """Process the prompt; returns (last-token logits, filled cache)."""
        cache = batch.get("cache")
        if cache is None:
            cache = self.init_cache(batch["tokens"].shape[0], slots)
        h, cache, _ = self._assemble(
            params, batch, "prefill", cache, window_override=window_override
        )
        return _lm_logits(params, h[:, -1:, :], self.config), cache

    def decode(
        self, params, cache: dict, batch: dict, window_override: int = -1
    ) -> tuple[Array, dict]:
        """One decode step. batch: {"tokens": (B,1), "pos": scalar, ...}."""
        h, cache, _ = self._assemble(
            params, batch, "decode", cache, window_override=window_override
        )
        return _lm_logits(params, h, self.config), cache

    # ---- diffusion-LM denoiser hook (see repro/models/diffusion.py) ----
    def backbone(
        self, params, h: Array, mode: str = "train", causal: bool = True,
        lengths: Array | None = None,
    ):
        """Run the block stack on externally-embedded states (B,S,d) —
        the diffusion-LM denoiser path.  No token prefix is present, so
        meta-token protection is off; enc-dec stacks run decoder-only.

        ``lengths`` ((B,) int32) marks per-row right-padding for
        mixed-seq-len batches: attention blocks mask pad keys out of every
        softmax.  Only meaningful for stacks whose cross-position mixing is
        attention (see ``repro.models.diffusion.MASKABLE_BLOCKS``)."""
        cfg = self.config
        ctx = BlockCtx(mode=mode, causal=causal, protected=0, lengths=lengths)
        h, _, aux = _stack(params, h, None, ctx, cfg)
        norm = L.layernorm if cfg.family == "audio" else L.rmsnorm
        return norm(params["final_norm"], h, cfg.norm_eps), aux


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
