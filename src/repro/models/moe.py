"""Mixture-of-Experts layer (Mixtral top-2, DeepSeek shared+routed top-6).

Dispatch strategies:

* ``dropping`` (default) — capacity-based token dispatch realized with
  scatter/gather per batch group (TPU adaptation: no giant one-hot dispatch
  einsum, so compiled FLOPs stay honest — dispatch moves bytes, the expert
  FFN does the FLOPs).  Tokens over capacity are dropped (residual passes
  through), the standard TPU training recipe.
* ``dense_mix`` — every expert runs on every token, outputs mixed by router
  probs.  O(E) FLOPs; used as the correctness oracle in tests and for tiny
  smoke configs.
* ``expert_parallel`` — shard_map + all_to_all path (see
  repro/parallel/expert_parallel.py); a §Perf optimization.

Router math is float32 throughout (bf16 routers destabilize top-k).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.parallel.ctx import constrain_dims

Array = jax.Array


def moe_specs(cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ff = m.d_ff_expert
    e = m.num_experts
    s = {
        "router": {"w": L.P((d, e), "fan_in")},
        "experts": {
            "wi": L.P((e, d, ff), "fan_in"),
            "wg": L.P((e, d, ff), "fan_in"),
            "wo": L.P((e, ff, d), "fan_in"),
        },
    }
    if m.num_shared:
        s["shared"] = L.mlp_specs(d, ff * m.num_shared, "silu")
    return s


def _router(p, x: Array, m) -> tuple[Array, Array, dict]:
    """Return (weights (..., k), ids (..., k), aux losses)."""
    logits = (x.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance loss + router z-loss
    e = m.num_experts
    density = jnp.mean(
        jax.nn.one_hot(ids, e, dtype=jnp.float32).sum(axis=-2), axis=tuple(range(ids.ndim - 1))
    ) / m.top_k
    mean_prob = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = {
        "moe_aux": e * jnp.sum(density * mean_prob),
        "moe_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return weights, ids, aux


def _expert_ffn(experts: dict, xs: Array) -> Array:
    """xs: (E, C, d) -> (E, C, d), batched over experts."""
    wi = experts["wi"].astype(xs.dtype)
    wg = experts["wg"].astype(xs.dtype)
    wo = experts["wo"].astype(xs.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wg)) * jnp.einsum(
        "ecd,edf->ecf", xs, wi
    )
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _expert_ffn_grouped(experts: dict, xs: Array) -> Array:
    """xs: (G, E, C, d) -> (G, E, C, d).  Layouts pinned so GSPMD keeps the
    token dims on the data axes and the expert hidden dim on the model axis
    (without this, the d-contraction gets sharded and every MoE layer
    all-reduces a (E, C, ff)-sized partial sum — see EXPERIMENTS.md §Perf).
    """
    wi = experts["wi"].astype(xs.dtype)
    wg = experts["wg"].astype(xs.dtype)
    wo = experts["wo"].astype(xs.dtype)
    # possible here; constrain token dims only and leave E/ff to the weights.
    xs = constrain_dims(xs, ("dp", None, None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xs, wg)) * jnp.einsum(
        "gecd,edf->gecf", xs, wi
    )
    out = jnp.einsum("gecf,efd->gecd", h, wo)
    return constrain_dims(out, ("dp", None, None, None))


def _dispatch_group(p, x: Array, m) -> tuple[Array, tuple, dict]:
    """Routing + capacity scatter for one token group. x: (S, d).
    Returns (buf (E, cap+1, d), combine-metadata, aux)."""
    s, d = x.shape
    k, e = m.top_k, m.num_experts
    cap = max(int(s * k / e * m.capacity_factor), 1)

    weights, ids, aux = _router(p, x, m)          # (S, k)
    flat_e = ids.reshape(-1)                      # (S*k,)
    flat_w = weights.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(s), k)

    # rank of each assignment within its expert (stable by token order)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(e))
    rank_sorted = jnp.arange(s * k) - start[sorted_e]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)

    keep = rank < cap
    slot = jnp.where(keep, rank, cap)             # overflow -> dump slot

    # scatter tokens into (E, cap+1, d); dump slot discarded
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[flat_e, slot].set(x[tok_idx], mode="drop")
    return buf, (flat_e, slot, keep, flat_w, tok_idx), aux


def _combine_group(out_buf: Array, meta: tuple, s: int) -> Array:
    """Gather expert outputs back to token order with top-k weights."""
    flat_e, slot, keep, flat_w, tok_idx = meta
    cap = out_buf.shape[1]
    d = out_buf.shape[-1]
    gathered = out_buf[flat_e, jnp.minimum(slot, cap - 1)]   # (S*k, d)
    gathered = gathered * keep[:, None].astype(gathered.dtype)
    return jnp.zeros((s, d), out_buf.dtype).at[tok_idx].add(
        gathered * flat_w[:, None].astype(gathered.dtype)
    )


def _dense_mix(p, x: Array, m) -> tuple[Array, dict]:
    """Reference: run all experts on all tokens. x: (..., d)."""
    weights, ids, aux = _router(p, x, m)
    e = m.num_experts
    d = x.shape[-1]
    flat = jnp.broadcast_to(x.reshape(1, -1, d), (e, x.size // d, d))
    outs = _expert_ffn(p["experts"], flat)        # (E, N, d)
    outs = outs.reshape((e,) + x.shape)           # (E, ..., d)
    sel = jnp.take_along_axis(
        jnp.moveaxis(outs, 0, -2),                # (..., E, d)
        ids[..., None],                           # (..., k, 1)
        axis=-2,
    )                                             # (..., k, d)
    mix = jnp.sum(sel * weights[..., None].astype(x.dtype), axis=-2)
    return mix, aux


def moe_ffn(p, x: Array, cfg) -> tuple[Array, dict]:
    """x: (B, S, d) -> (B, S, d), plus aux losses."""
    m = cfg.moe
    b, s, d = x.shape
    if m.dispatch == "dense_mix":
        out, aux = _dense_mix(p, x, m)
    elif m.dispatch == "dropping":
        # split long sequences into dispatch groups so the (E, C, d)
        # capacity buffer stays bounded (§Perf iteration B3)
        g = min(m.dispatch_group, s) if s % min(m.dispatch_group, s) == 0 else s
        ng = b * (s // g)
        xg = x.reshape(ng, g, d)
        # vmap carries only the index math; the expert FFN runs as one
        # grouped einsum with pinned layouts (see _expert_ffn_grouped)
        buf, meta, aux_stack = jax.vmap(
            lambda xx: _dispatch_group(p, xx, m)
        )(xg)
        buf = constrain_dims(buf, ("dp", None, None, None))
        cap = buf.shape[2] - 1
        out_buf = _expert_ffn_grouped(p["experts"], buf[:, :, :cap])
        out = jax.vmap(lambda ob, mt: _combine_group(ob, mt, g))(out_buf, meta)
        out = out.reshape(b, s, d)
        aux = jax.tree.map(jnp.mean, aux_stack)
    else:
        raise ValueError(f"unknown MoE dispatch {m.dispatch!r}")
    if m.num_shared:
        out = out + L.mlp(p["shared"], x, "silu")
    return out, aux
