"""GQA attention: projections, KV cache, and three SDPA implementations.

* ``naive``   — materializes (Sq, Sk) scores; smoke tests / short seq.
* ``chunked`` — XLA-native streaming-softmax over KV chunks (lax.scan).
  This is the dry-run / long-context path: memory is O(Sq * chunk) and the
  FLOPs are what a TPU flash kernel would do, so ``cost_analysis`` stays
  honest on CPU where a Pallas TPU kernel cannot compile.
* ``pallas``  — the TPU-target flash kernels in :mod:`repro.kernels`
  (validated in interpret mode on CPU; the deployment fast path).

Masking is positional: every key slot carries an absolute position (-1 for
invalid ring-buffer slots), so full causal, sliding-window, and ring-buffer
decode all share one code path.
"""

from __future__ import annotations

import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# fast-path fallback: loud, observable, and the only place impl is rewritten
# ---------------------------------------------------------------------------

#: callables ``(impl, reason) -> None`` notified whenever sdpa rewrites a
#: requested fast impl to chunked.  The serving executor registers one to
#: drive the ``sampler_masked_fallback_total`` counter — the permanent
#: canary that fused mixed-length traffic regressed off the fast kernels.
#: Observers fire at trace time, so each count is a compiled-program
#: materialization that runs the slow path, not a per-request count.
_fallback_observers: list[Callable[[str, str], None]] = []
_warned_fallbacks: set[tuple[str, str]] = set()


def register_fallback_observer(fn: Callable[[str, str], None]) -> Callable:
    _fallback_observers.append(fn)
    return fn


def unregister_fallback_observer(fn: Callable[[str, str], None]) -> None:
    try:
        _fallback_observers.remove(fn)
    except ValueError:
        pass


def _fallback_to_chunked(impl: str, reason: str) -> str:
    """Rewrite a requested fast impl to ``chunked``: warn once per
    (impl, reason) and notify every registered observer.  Any config that
    still can't ride the fast kernels goes through here — never an inline
    silent rewrite."""
    key = (impl, reason)
    if key not in _warned_fallbacks:
        _warned_fallbacks.add(key)
        warnings.warn(
            f"sdpa: requested impl={impl!r} unavailable ({reason}); "
            "falling back to chunked SDPA. This trades the fused "
            "fast-attention kernel for the slow path — check "
            "sampler_masked_fallback_total if this is serving traffic.",
            RuntimeWarning,
            stacklevel=3,
        )
    for fn in list(_fallback_observers):
        fn(impl, reason)
    return "chunked"


# ---------------------------------------------------------------------------
# masking
# ---------------------------------------------------------------------------


def _kv_mask_bias(kv_mask: Array) -> Array:
    """(B, Sk) per-row key-validity mask -> additive bias.

    Valid keys get an exact ``0.0`` bias (``score + 0.0 == score``
    bitwise), so a right-padded batch's valid positions score exactly what
    the unpadded batch would.
    """
    return jnp.where(kv_mask, 0.0, NEG_INF).astype(jnp.float32)


def _mask_bias(
    q_pos: Array, kv_pos: Array, window: int, causal: bool, protected: int = 0
) -> Array:
    """(Sq, Sk) additive bias from absolute positions."""
    q = q_pos[:, None]
    k = kv_pos[None, :]
    valid = k >= 0
    if causal:
        valid &= k <= q
    if window > 0:
        in_window = k > q - window
        if protected > 0:  # attention sinks are always visible
            in_window |= k < protected
        valid &= in_window
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def _softcap(x: Array, cap: float) -> Array:
    if cap > 0.0:
        return cap * jnp.tanh(x / cap)
    return x


# ---------------------------------------------------------------------------
# SDPA implementations. q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd)
# ---------------------------------------------------------------------------


def _naive_sdpa(
    q, k, v, q_pos, kv_pos, *, window, causal, softcap, protected=0,
    kv_mask=None,
):
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = _softcap(scores * (hd**-0.5), softcap)
    scores = scores + _mask_bias(q_pos, kv_pos, window, causal, protected)
    if kv_mask is not None:  # per-row pad-key mask (mixed-seq-len batches)
        scores = scores + _kv_mask_bias(kv_mask)[:, None, None, None, :]
    w = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (e.g. an all-pad row) -> zeros, matching the Pallas
    # kernel and the ref oracle, instead of softmax-of-garbage
    any_valid = jnp.max(scores, axis=-1, keepdims=True) > NEG_INF / 2
    w = jnp.where(any_valid, w, 0.0)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd)


def _chunked_sdpa(
    q, k, v, q_pos, kv_pos, *, window, causal, softcap, chunk, protected=0,
    kv_mask=None,
):
    """Streaming-softmax attention, scanned over KV chunks."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    nchunks = -(-sk // chunk)
    pad = nchunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)
        if kv_mask is not None:
            kv_mask = jnp.pad(kv_mask, ((0, 0), (0, pad)))
    kc = k.reshape(b, nchunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(nchunks, chunk)
    mc = (
        None
        if kv_mask is None
        else kv_mask.reshape(b, nchunks, chunk).transpose(1, 0, 2)
    )

    qg = (q * (hd**-0.5)).reshape(b, sq, kv, g, hd)
    acc0 = jnp.zeros((b, sq, kv, g, hd), jnp.float32)
    m0 = jnp.full((b, sq, kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, g), jnp.float32)

    def body(carry, xs):
        acc, m, l = carry
        if mc is None:
            kj, vj, pj = xs
        else:
            kj, vj, pj, mj = xs
        s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kj).astype(jnp.float32)
        s = _softcap(s, softcap)
        bias = _mask_bias(q_pos, pj, window, causal, protected)  # (sq, chunk)
        s = s + bias[None, :, None, None, :]
        if mc is not None:  # per-row pad-key mask (mixed-seq-len batches)
            s = s + _kv_mask_bias(mj)[:, None, None, None, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard both exps below the mask floor so fully-masked rows keep
        # (acc, l) at exact zero and finalize to zeros (kernel semantics)
        p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[..., None]), 0.0)
        scale = jnp.where(m > NEG_INF / 2, jnp.exp(m - m_new), 0.0)
        acc = acc * scale[..., None] + jnp.einsum(
            "bqkgs,bskd->bqkgd", p.astype(vj.dtype), vj
        ).astype(jnp.float32)
        l = l * scale + jnp.sum(p, axis=-1)
        return (acc, m_new, l), None

    xs = (kc, vc, pc) if mc is None else (kc, vc, pc, mc)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), xs)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _banded_sdpa(
    q, k, v, q_pos, kv_pos, *, window, softcap, chunk, protected, kv_mask=None
):
    """Sliding-window attention that only touches in-band KV blocks.

    §Perf optimization: the plain chunked path computes every (q, kv) block
    and masks — at 32k tokens with a 4k window that is 8x wasted FLOPs and
    score memory.  Here q is cut into window-sized blocks; block i attends
    to kv blocks {i-1, i} (which cover the whole (q-W, q] band), plus the
    protected attention-sink prefix.  Requires aligned full-sequence layout
    (q_pos == kv_pos == arange(S)), which is how train/prefill call it.
    ``kv_mask`` (per-row pad-key mask) is sliced along the same band so
    right-padded mixed-seq-len batches stay on this fast path.
    """
    b, sq, h, hd = q.shape
    w = window
    nblocks = -(-sq // w)
    pad = nblocks * w - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=-(10**9))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)
        if kv_mask is not None:
            kv_mask = jnp.pad(kv_mask, ((0, 0), (0, pad)))

    def block(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * w, w, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * w, w, axis=0)
        lo = jnp.maximum(i - 1, 0) * w
        ks = jax.lax.dynamic_slice_in_dim(k, lo, 2 * w, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, lo, 2 * w, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(kv_pos, lo, 2 * w, axis=0)
        km = (
            None
            if kv_mask is None
            else jax.lax.dynamic_slice_in_dim(kv_mask, lo, 2 * w, axis=1)
        )
        if protected:
            # invalidate sink positions inside the band slice (early blocks
            # already cover them) before prepending the dedicated sink copy
            kp = jnp.where(kp < protected, -1, kp)
            ks = jnp.concatenate([k[:, :protected], ks], axis=1)
            vs = jnp.concatenate([v[:, :protected], vs], axis=1)
            kp = jnp.concatenate([kv_pos[:protected], kp], axis=0)
            if km is not None:
                km = jnp.concatenate([kv_mask[:, :protected], km], axis=1)
        return _chunked_sdpa(
            qs, ks, vs, qp, kp,
            window=window, causal=True, softcap=softcap,
            chunk=min(chunk, 2 * w), protected=protected, kv_mask=km,
        )

    outs = [block(jnp.int32(i)) for i in range(nblocks)] if nblocks <= 4 else None
    if outs is not None:
        out = jnp.concatenate(outs, axis=1)
    else:
        out = jax.lax.map(block, jnp.arange(nblocks)).transpose(1, 0, 2, 3, 4)
        out = out.reshape(b, nblocks * w, h, hd)
    return out[:, :sq]


def sdpa(
    q: Array,
    k: Array,
    v: Array,
    q_pos: Array,
    kv_pos: Array,
    *,
    window: int = 0,
    causal: bool = True,
    softcap: float = 0.0,
    impl: str = "auto",
    chunk: int = 1024,
    protected: int = 0,
    kv_mask: Array | None = None,
) -> Array:
    """``kv_mask`` is an optional (B, Sk) per-row key-validity mask — the
    mixed-seq-len serving path marks right-padding pad positions invalid so
    they get zero attention weight.  Every impl takes it natively: the
    Pallas flash kernel carries it as a BlockSpec operand and the banded
    fast path slices it along the band, so masked mixed-length batches run
    the same fast kernels as unmasked ones.  The only remaining rewrite is
    an explicitly requested ``banded`` whose layout preconditions (causal,
    windowed, aligned full-sequence) don't hold — that goes through
    :func:`_fallback_to_chunked`, which warns once and notifies the
    fallback observers (``sampler_masked_fallback_total``)."""
    sq, sk = q.shape[1], k.shape[1]
    if (
        impl in ("auto", "chunked", "banded")
        and causal
        and window > 0
        and sq == sk
        and sq >= 4 * window
    ):
        return _banded_sdpa(
            q, k, v, q_pos, kv_pos,
            window=window, softcap=softcap, chunk=chunk, protected=protected,
            kv_mask=kv_mask,
        )
    if impl == "banded":
        # layout preconditions unmet (non-causal, unwindowed, or sq != sk)
        impl = _fallback_to_chunked("banded", "banded-layout-unmet")
    if impl == "auto":
        impl = "naive" if sq * sk <= 1024 * 2048 else "chunked"
    if impl == "naive":
        return _naive_sdpa(
            q, k, v, q_pos, kv_pos,
            window=window, causal=causal, softcap=softcap, protected=protected,
            kv_mask=kv_mask,
        )
    if impl == "chunked":
        return _chunked_sdpa(
            q, k, v, q_pos, kv_pos,
            window=window, causal=causal, softcap=softcap,
            chunk=min(chunk, max(sk, 128)), protected=protected,
            kv_mask=kv_mask,
        )
    if impl == "pallas":
        from repro.kernels import ops as kops

        return kops.flash_attention(
            q, k, v, q_pos, kv_pos,
            window=window, causal=causal, softcap=softcap,
            protected=protected, kv_mask=kv_mask,
        )
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# KV cache (per layer). Slots carry absolute positions; -1 = empty.
# Ring buffers (slots < max_position) implement sliding-window decode.
# ---------------------------------------------------------------------------


def cache_specs(batch: int, slots: int, kv_heads: int, head_dim: int) -> dict:
    return {
        "k": L.P((batch, slots, kv_heads, head_dim), "zeros"),
        "v": L.P((batch, slots, kv_heads, head_dim), "zeros"),
        "pos": L.P((slots,), "zeros"),  # stored as int32 via init_cache
    }


def init_cache(
    batch: int, slots: int, kv_heads: int, head_dim: int, dtype,
    quant: bool = False,
):
    if quant:  # int8 entries + per-(slot, head) scales (§Perf: decode is
        # memory-bound on cache streaming; int8 halves the bytes)
        return {
            "k": jnp.zeros((batch, slots, kv_heads, head_dim), jnp.int8),
            "v": jnp.zeros((batch, slots, kv_heads, head_dim), jnp.int8),
            "k_scale": jnp.zeros((batch, slots, kv_heads, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, slots, kv_heads, 1), jnp.float32),
            "pos": jnp.full((slots,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, slots, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, slots, kv_heads, head_dim), dtype),
        "pos": jnp.full((slots,), -1, jnp.int32),
    }


def abstract_cache(
    batch: int, slots: int, kv_heads: int, head_dim: int, dtype,
    quant: bool = False,
):
    """ShapeDtypeStruct mirror of init_cache (no allocation)."""
    if quant:
        return {
            "k": jax.ShapeDtypeStruct((batch, slots, kv_heads, head_dim), jnp.int8),
            "v": jax.ShapeDtypeStruct((batch, slots, kv_heads, head_dim), jnp.int8),
            "k_scale": jax.ShapeDtypeStruct((batch, slots, kv_heads, 1), jnp.float32),
            "v_scale": jax.ShapeDtypeStruct((batch, slots, kv_heads, 1), jnp.float32),
            "pos": jax.ShapeDtypeStruct((slots,), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, slots, kv_heads, head_dim), dtype),
        "v": jax.ShapeDtypeStruct((batch, slots, kv_heads, head_dim), dtype),
        "pos": jax.ShapeDtypeStruct((slots,), jnp.int32),
    }


def _quantize(x: Array) -> tuple[Array, Array]:
    """Per-(token, head) symmetric int8. x: (B, S, KV, hd)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequant(q: Array, scale: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def cache_kv(cache: dict, dtype) -> tuple[Array, Array]:
    """Read K/V from a (possibly quantized) cache."""
    if cache["k"].dtype == jnp.int8:
        return (
            _dequant(cache["k"], cache["k_scale"], dtype),
            _dequant(cache["v"], cache["v_scale"], dtype),
        )
    return cache["k"], cache["v"]


def cache_insert(cache: dict, k: Array, v: Array, pos: Array, protected: int = 0) -> dict:
    """Insert one step (S=1) at absolute position `pos` (scalar).

    ``protected`` reserves the first slots for never-evicted prefix tokens
    (attention sinks / Hymba meta tokens) when the cache is a ring buffer.
    """
    slots = cache["k"].shape[1]
    if protected > 0 and protected < slots:
        ring = slots - protected
        slot = jnp.where(
            pos < protected, pos, protected + (pos - protected) % ring
        )
    else:
        slot = pos % slots
    out = dict(cache)
    if cache["k"].dtype == jnp.int8:
        kq, ks = _quantize(k)
        vq, vs = _quantize(v)
        out["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=1)
        out["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=1)
        out["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks, slot, axis=1
        )
        out["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs, slot, axis=1
        )
    else:
        out["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1
        )
        out["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1
        )
    out["pos"] = jax.lax.dynamic_update_index_in_dim(
        cache["pos"], pos.astype(jnp.int32), slot, axis=0
    )
    return out


def cache_fill(cache: dict, k: Array, v: Array, start: Array) -> dict:
    """Prefill: write S consecutive steps starting at `start` (ring-aware
    only for start=0 and S<=slots; prefill always satisfies this)."""
    s = k.shape[1]
    slots = cache["k"].shape[1]
    pos = start + jnp.arange(s, dtype=jnp.int32)
    if s > slots:
        # keep only the last `slots` entries (window prefill)
        k, v, pos = k[:, -slots:], v[:, -slots:], pos[-slots:]
        s = slots
    out = dict(cache)
    if cache["k"].dtype == jnp.int8:
        kq, ks = _quantize(k)
        vq, vs = _quantize(v)
        out["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, start % slots, axis=1)
        out["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, start % slots, axis=1)
        out["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks, start % slots, axis=1
        )
        out["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs, start % slots, axis=1
        )
    else:
        out["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), start % slots, axis=1
        )
        out["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), start % slots, axis=1
        )
    out["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos, start % slots, axis=0
    )
    return out


# ---------------------------------------------------------------------------
# Full GQA attention layer (projections + rope + cache + sdpa)
# ---------------------------------------------------------------------------


def attention_specs(cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": L.linear_specs(d, h * hd, bias=cfg.qkv_bias),
        "wk": L.linear_specs(d, kv * hd, bias=cfg.qkv_bias),
        "wv": L.linear_specs(d, kv * hd, bias=cfg.qkv_bias),
        "wo": L.linear_specs(h * hd, d),
    }


def attention(
    p: dict,
    x: Array,
    cfg,
    *,
    mode: str,
    cache: dict | None = None,
    pos: Array | None = None,
    window: int = 0,
    causal: bool = True,
    cross_kv: tuple[Array, Array] | None = None,
    protected: int = 0,
    lengths: Array | None = None,
) -> tuple[Array, dict | None]:
    """mode: 'train' | 'prefill' | 'decode'. Returns (out, new_cache).

    ``lengths`` ((B,) int32, train-mode full-sequence layout only) marks
    positions >= lengths[b] as right-padding: those keys are masked out of
    every row's softmax, so a padded batch's valid positions attend to
    exactly the keys an unpadded batch would."""
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    q = L.linear(p["wq"], x).reshape(b, s, h, hd)

    if cross_kv is not None:
        # cross attention (Whisper decoder): kv from encoder, no cache mgmt
        ek, ev = cross_kv
        q_pos = jnp.zeros((s,), jnp.int32) if pos is None else (
            pos + jnp.arange(s, dtype=jnp.int32)
        )
        kv_pos = jnp.arange(ek.shape[1], dtype=jnp.int32)
        out = sdpa(
            q, ek, ev, q_pos, kv_pos,
            window=0, causal=False, softcap=cfg.attn_logit_softcap,
            impl=_resolve_impl(cfg, s, ek.shape[1]), chunk=cfg.attn_chunk,
        )
        return L.linear(p["wo"], out.reshape(b, s, h * hd)), cache

    k = L.linear(p["wk"], x).reshape(b, s, kvh, hd)
    v = L.linear(p["wv"], x).reshape(b, s, kvh, hd)

    if mode in ("train", "prefill"):
        positions = jnp.arange(s, dtype=jnp.int32)
    else:  # decode: single token at absolute position `pos`
        positions = jnp.atleast_1d(jnp.asarray(pos, jnp.int32))

    if cfg.use_rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    new_cache = cache
    if mode == "decode":
        assert cache is not None
        new_cache = cache_insert(cache, k, v, positions[0], protected)
        k_all, v_all = cache_kv(new_cache, k.dtype)
        kv_pos = new_cache["pos"]
        out = sdpa(
            q, k_all, v_all, positions, kv_pos,
            window=window, causal=True, softcap=cfg.attn_logit_softcap,
            impl=_resolve_impl(cfg, 1, k_all.shape[1]), chunk=cfg.attn_chunk,
            protected=protected,
        )
    else:
        if mode == "prefill" and cache is not None:
            new_cache = cache_fill(cache, k, v, jnp.int32(0))
        kv_mask = (
            None
            if lengths is None
            else jnp.arange(s, dtype=jnp.int32) < lengths[:, None]
        )
        out = sdpa(
            q, k, v, positions, positions,
            window=window, causal=causal, softcap=cfg.attn_logit_softcap,
            impl=_resolve_impl(cfg, s, s), chunk=cfg.attn_chunk,
            protected=protected, kv_mask=kv_mask,
        )

    return L.linear(p["wo"], out.reshape(b, s, h * hd)), new_cache


def _resolve_impl(cfg, sq: int, sk: int) -> str:
    if cfg.attention_impl != "auto":
        return cfg.attention_impl
    return "naive" if sq * sk <= 1024 * 2048 else "chunked"
