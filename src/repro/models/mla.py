"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV activations are compressed into a low-rank latent c_kv (kv_lora_rank)
plus a single shared RoPE key head; the cache stores only
(kv_lora_rank + rope_dim) per token — the paper's 93% KV-cache reduction.

Two execution forms:
* train/prefill — expand c_kv to per-head K/V and run standard SDPA
  (no cache reuse, expansion is a single matmul over the sequence).
* decode — the *absorbed* form: W_kb is folded into the query and W_vb into
  the output so attention runs directly in latent space against the
  compressed cache.  This is the production DeepSeek serving trick and our
  paper-faithful baseline for decode shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import sdpa

Array = jax.Array


def mla_specs(cfg) -> dict:
    a = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qd = a.qk_nope_head_dim + a.qk_rope_head_dim
    return {
        "wq": L.linear_specs(d, h * qd),
        "wkv_a": L.linear_specs(d, a.kv_lora_rank + a.qk_rope_head_dim),
        "ckv_norm": L.rmsnorm_specs(a.kv_lora_rank),
        "wkv_b": L.linear_specs(
            a.kv_lora_rank, h * (a.qk_nope_head_dim + a.v_head_dim)
        ),
        "wo": L.linear_specs(h * a.v_head_dim, d),
    }


def _project_q(p, x, cfg, positions):
    a = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q = L.linear(p["wq"], x).reshape(b, s, h, a.qk_nope_head_dim + a.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [a.qk_nope_head_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _compress_kv(p, x, cfg, positions):
    a = cfg.mla
    kv_a = L.linear(p["wkv_a"], x)
    c_kv, k_rope = jnp.split(kv_a, [a.kv_lora_rank], axis=-1)
    c_kv = L.rmsnorm(p["ckv_norm"], c_kv, cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]          # (B,S,r), (B,S,rope)


def mla_train(p, x: Array, cfg, mode: str = "train", cache=None, lengths=None):
    """Full-sequence MLA (train / prefill). Returns (out, cache).

    ``lengths`` ((B,) int32) marks right-padding: pad keys are masked out
    of every row's softmax (the attention path is causal, so valid rows
    never see pad keys anyway — the mask makes the guarantee explicit and
    keeps MLA on the same mixed-seq-len contract as GQA attention)."""
    a = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    positions = jnp.arange(s, dtype=jnp.int32)
    kv_mask = None if lengths is None else positions[None, :] < lengths[:, None]

    q_nope, q_rope = _project_q(p, x, cfg, positions)
    c_kv, k_rope = _compress_kv(p, x, cfg, positions)

    kv = L.linear(p["wkv_b"], c_kv).reshape(
        b, s, h, a.qk_nope_head_dim + a.v_head_dim
    )
    k_nope, v = jnp.split(kv, [a.qk_nope_head_dim], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], q_rope.shape[:2] + (h, a.qk_rope_head_dim))],
        axis=-1,
    )
    # pad v to qk head dim for the shared sdpa, then slice back
    out = sdpa(
        q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, q.shape[-1] - v.shape[-1]))),
        positions, positions,
        window=0, causal=True, softcap=0.0,
        impl="naive" if s * s <= 1024 * 2048 else "chunked",
        chunk=cfg.attn_chunk, kv_mask=kv_mask,
    )[..., : a.v_head_dim]

    if mode == "prefill":
        assert cache is not None
        slots = cache["ckv"].shape[1]
        take = min(s, slots)
        pos_arr = positions[-take:]
        cache = {
            "ckv": jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], c_kv[:, -take:].astype(cache["ckv"].dtype), 0, axis=1
            ),
            "krope": jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], k_rope[:, -take:].astype(cache["krope"].dtype), 0, axis=1
            ),
            "pos": jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], pos_arr, 0, axis=0
            ),
        }
    return L.linear(p["wo"], out.reshape(b, s, -1)), cache


def mla_decode(p, x: Array, cfg, cache: dict, pos: Array):
    """Absorbed-form single-token decode against the compressed cache."""
    a = cfg.mla
    b, s, _ = x.shape  # s == 1
    h = cfg.num_heads
    positions = jnp.atleast_1d(jnp.asarray(pos, jnp.int32))

    q_nope, q_rope = _project_q(p, x, cfg, positions)        # (B,1,H,*)
    c_kv_new, k_rope_new = _compress_kv(p, x, cfg, positions)

    slots = cache["ckv"].shape[1]
    slot = positions[0] % slots
    cache = {
        "ckv": jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], c_kv_new.astype(cache["ckv"].dtype), slot, axis=1
        ),
        "krope": jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope_new.astype(cache["krope"].dtype), slot, axis=1
        ),
        "pos": jax.lax.dynamic_update_index_in_dim(
            cache["pos"], positions[0], slot, axis=0
        ),
    }

    wkv_b = p["wkv_b"]["w"].reshape(
        a.kv_lora_rank, h, a.qk_nope_head_dim + a.v_head_dim
    )
    w_kb = wkv_b[..., : a.qk_nope_head_dim]     # (r, H, nope)
    w_vb = wkv_b[..., a.qk_nope_head_dim :]     # (r, H, v)

    # absorb W_kb into the query -> latent-space query
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_kb.astype(q_nope.dtype))

    ckv = cache["ckv"]                          # (B, S, r)
    krope = cache["krope"]                      # (B, S, rope)
    scale = (a.qk_nope_head_dim + a.qk_rope_head_dim) ** -0.5
    scores = (
        jnp.einsum("bshr,btr->bhst", q_lat, ckv.astype(q_lat.dtype))
        + jnp.einsum("bshr,btr->bhst", q_rope, krope.astype(q_rope.dtype))
    ).astype(jnp.float32) * scale
    valid = cache["pos"] >= 0
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", w.astype(ckv.dtype), ckv)   # latent ctx
    out = jnp.einsum("bshr,rhv->bshv", ctx, w_vb.astype(ctx.dtype))
    return L.linear(p["wo"], out.reshape(b, s, -1)), cache


def mla_init_cache(cfg, batch: int, slots: int, dtype):
    a = cfg.mla
    return {
        "ckv": jnp.zeros((batch, slots, a.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, slots, a.qk_rope_head_dim), dtype),
        "pos": jnp.full((slots,), -1, jnp.int32),
    }


def mla_abstract_cache(cfg, batch: int, slots: int, dtype):
    a = cfg.mla
    return {
        "ckv": jax.ShapeDtypeStruct((batch, slots, a.kv_lora_rank), dtype),
        "krope": jax.ShapeDtypeStruct((batch, slots, a.qk_rope_head_dim), dtype),
        "pos": jax.ShapeDtypeStruct((slots,), jnp.int32),
    }
