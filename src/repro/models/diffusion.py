"""Diffusion-LM wrapper: any backbone becomes an eps-prediction denoiser.

This is how the paper's solver integrates with the assigned architectures
(DESIGN.md §3): x_t lives in embedding space (B, S, d); the wrapper adds
sinusoidal-time conditioning, runs the backbone stack (non-causal where the
family supports it), and projects to a noise estimate.  Each NFE of an
ERA-Solver sampling run is exactly one backbone forward.

Training objective: Eq. 5 of the paper (simplified eps-matching loss).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.schedules import NoiseSchedule
from repro.models import layers as L
from repro.models.model import Model

Array = jax.Array

#: block kinds safe to run right-padded with per-row ``lengths``: a padded
#: row's valid positions compute exactly the unpadded run's math.  Two ways
#: a kind earns membership:
#:
#: * **maskable attention** — every cross-position mixing is an attention
#:   softmax that takes the per-row kv_mask (dense / moe / enc / hymba_* /
#:   mla_moe attention halves, xdec self-attention): pad keys get an exact
#:   ``-1e30`` bias, valid keys an exact ``+0.0``.  All three SDPA impls
#:   (naive / chunked / pallas+banded flash kernels) carry the mask
#:   natively, so fused masked batches stay on the fast kernels.
#: * **directional scans** — SSM / recurrent kinds (mamba inside hymba_*,
#:   mlstm, slstm) mix positions strictly left-to-right, so right-padding
#:   can never reach a prefix position's output (prefix-safety wall:
#:   ``tests/test_prefix_safety.py``; see the contract note in
#:   :mod:`repro.models.ssm`).
#:
#: The pad tail itself is handled by :meth:`DiffusionLM.eps`, which zeroes
#: eps at pad positions so padded tails stay inert across a sampling run.
MASKABLE_BLOCKS = frozenset(
    {
        "dense", "moe", "enc", "xdec",
        "mlstm", "slstm", "hymba_swa", "hymba_full",
        "mla_moe",
    }
)


def diffusion_specs(model: Model) -> dict:
    d = model.config.d_model
    return {
        "backbone": model.specs(),
        "time_mlp": L.time_mlp_specs(d),
        "in_proj": L.linear_specs(d, d),
        "eps_head": {"w": L.P((d, d), "zeros"), "b": L.P((d,), "zeros")},
    }


@dataclasses.dataclass(frozen=True)
class DiffusionLM:
    model: Model
    causal: bool = False  # attention families denoise bidirectionally

    @property
    def config(self):
        return self.model.config

    def specs(self) -> dict:
        return diffusion_specs(self.model)

    def init(self, key: jax.Array) -> dict:
        return L.init_params(self.specs(), key, self.config.param_dtype)

    def init_abstract(self) -> dict:
        return L.abstract_params(self.specs(), self.config.param_dtype)

    @property
    def supports_length_masking(self) -> bool:
        """Can this denoiser run right-padded mixed-seq-len batches such
        that every valid position's output is exactly the unpadded run's?
        True iff every block kind is in :data:`MASKABLE_BLOCKS` — maskable
        attention or a right-pad prefix-safe directional scan.  The serving
        engine consults this before seq-bucketing and falls back to
        exact-shape grouping otherwise (counted by
        ``sampler_masked_fallback_total``)."""
        return all(kind in MASKABLE_BLOCKS for kind, _ in self.config.blocks)

    def eps(
        self, params: dict, x_t: Array, t: Array,
        lengths: Array | None = None,
    ) -> Array:
        """Noise prediction eps_theta(x_t, t). x_t: (B, S, d); t a scalar
        shared by the batch, or per-row times shaped (B,) / (B, 1, 1)
        (mixed-NFE and adaptive solvers condition each row on its own
        time).

        ``lengths`` ((B,) int32) marks per-row right-padding: pad keys are
        masked out of every attention softmax (valid positions see exactly
        the unpadded batch's math) and the returned eps is zeroed at pad
        positions, so a padded row's tail stays inert and bounded across a
        whole sampling run instead of evolving garbage."""
        cfg = self.config
        tcond = L.time_mlp(params["time_mlp"], jnp.reshape(t, (-1,)))  # (1|B, d)
        h = L.linear(params["in_proj"], x_t.astype(cfg.dtype))
        h = h + tcond[:, None, :].astype(h.dtype)
        h, _ = self.model.backbone(
            params["backbone"], h, mode="train", causal=self.causal,
            lengths=lengths,
        )
        eps = h @ params["eps_head"]["w"].astype(h.dtype) + params["eps_head"][
            "b"
        ].astype(h.dtype)
        # zero-init head -> identity-ish residual from x_t at step 0
        out = (eps.astype(jnp.float32) + x_t.astype(jnp.float32)).astype(
            x_t.dtype
        )
        if lengths is not None:
            valid = jnp.arange(out.shape[1], dtype=jnp.int32) < lengths[:, None]
            out = jnp.where(valid[..., None], out, 0.0)
        return out

    def eps_fn(self, params: dict, lengths: Array | None = None):
        """Closure matching the solver API: eps_fn(x, t) -> eps.  With
        ``lengths``, the closure denoises a right-padded batch with pad
        positions masked (see :meth:`eps`)."""
        return lambda x, t: self.eps(params, x, t, lengths=lengths)

    def loss(
        self, params: dict, batch: dict, rng: jax.Array, schedule: NoiseSchedule
    ) -> tuple[Array, dict]:
        """Eps-matching diffusion loss on clean latents batch["latents"]."""
        x0 = batch["latents"].astype(jnp.float32)
        kt, ke = jax.random.split(rng)
        b = x0.shape[0]
        # low-discrepancy time sampling across the batch
        u = (jax.random.uniform(kt, ()) + jnp.arange(b) / b) % 1.0
        t = schedule.t_end + (schedule.t_begin - schedule.t_end) * u
        eps = jax.random.normal(ke, x0.shape, jnp.float32)
        a = schedule.alpha(t)[:, None, None]
        s = schedule.sigma(t)[:, None, None]
        x_t = a * x0 + s * eps
        # per-sample t: vmap the scalar-t eps over the batch
        pred = jax.vmap(
            lambda xi, ti: self.eps(params, xi[None], ti)[0]
        )(x_t.astype(self.config.dtype), t)
        mse = jnp.mean((pred.astype(jnp.float32) - eps) ** 2)
        return mse, {"diffusion_mse": mse}
