"""State-space / recurrent blocks: Mamba (Hymba heads) and xLSTM cells.

TPU adaptation notes (DESIGN.md §4): the CUDA "selective scan" kernel of
Mamba is replaced by a *chunked* linear-recurrence scan — ``lax.scan`` over
sequence chunks with an associative scan inside each chunk — which keeps the
live state tensor at (B, chunk, d_inner, N) instead of (B, S, d_inner, N).
xLSTM's sLSTM is an inherently sequential recurrence (recurrent weights),
implemented as a time scan; mLSTM (matrix memory) uses the same chunked
pattern as Mamba.

Right-pad prefix-safety (the mixed-seq-len masking contract): every scan
in this module is strictly left-to-right — ``causal_conv1d`` left-pads,
the chunked recurrences carry state forward only, and the intra-chunk
mLSTM scores are tril-masked to exact zeros before any contraction — so a
right-padded row's outputs at positions ``< length`` are identical to the
exact-shape run's.  Two structural facts make the identity *bitwise*, not
just mathematical: (1) ``jax.lax.associative_scan``'s combine tree for
prefix element ``p`` depends only on ``p`` (Brent–Kung interleave), not on
the scanned length, so a longer padded axis doesn't re-associate prefix
sums; (2) chunk boundaries inside the prefix coincide between the exact
and padded runs (``chunk = min(chunk, s)`` either yields the same chunking
over the prefix, or both runs put the whole prefix in their first chunk),
and masked/pad slots contribute exact ``+0.0`` terms to the fixed-shape
contractions.  ``tests/test_prefix_safety.py`` walls this per block kind;
it is what lets SSM kinds join ``MASKABLE_BLOCKS`` in
:mod:`repro.models.diffusion`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array


# ---------------------------------------------------------------------------
# Linear recurrence helpers:  h_t = a_t * h_{t-1} + b_t   (associative)
# ---------------------------------------------------------------------------


def _assoc_op(l, r):
    al, bl = l
    ar, br = r
    return al * ar, br + ar * bl


def chunked_linear_scan(a: Array, b: Array, h0: Array, chunk: int):
    """Scan h_t = a_t h_{t-1} + b_t over axis 1 (time).

    a: (B, S, ...) gate — trailing dims may be 1 (broadcast against b).
    b: (B, S, ...);  h0: (B, ...) matching b's trailing dims.
    Returns (h_all (B, S, ...), h_last).
    """
    bsz, s = b.shape[0], b.shape[1]
    chunk = min(chunk, s)
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad)) + ((0, 0),) * (b.ndim - 2))
    ac = jnp.moveaxis(a.reshape((bsz, nchunks, chunk) + a.shape[2:]), 1, 0)
    bc = jnp.moveaxis(b.reshape((bsz, nchunks, chunk) + b.shape[2:]), 1, 0)

    def body(h, xs):
        aj, bj = xs                                  # (B, chunk, ...)
        # fold carry into the first step of the chunk
        bj = bj.at[:, 0].add(aj[:, 0] * h)
        _, hh = jax.lax.associative_scan(_assoc_op, (aj, bj), axis=1)
        return hh[:, -1], hh

    h_last, hs = jax.lax.scan(body, h0, (ac, bc))
    hs = jnp.moveaxis(hs, 0, 1).reshape((bsz, nchunks * chunk) + b.shape[2:])
    return hs[:, :s], h_last


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — used by Hymba's SSM heads
# ---------------------------------------------------------------------------


def chunked_ssm_outputs(
    dt32: Array, x32: Array, a: Array, bmat: Array, c: Array,
    h0: Array, chunk: int,
):
    """Fused selective scan: discretize + recur + read out, per chunk.

    §Perf: materializing the discretized (B, S, d_inner, N) tensors (a_bar,
    dt*B*x) before the scan dominated Hymba train memory (98 GB/device).
    Here BOTH the discretization and the <c_t, h_t> readout happen inside
    each chunk body, so only (B, chunk, d_inner, N) tensors ever exist.

    dt32, x32: (B, S, d); a: (d, N); bmat, c: (B, S, N); h0: (B, d, N).
    Returns (y (B, S, d), h_last).
    """
    bsz, s = x32.shape[0], x32.shape[1]
    chunk = min(chunk, s)
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        p2 = ((0, 0), (0, pad), (0, 0))
        dt32 = jnp.pad(dt32, p2)  # dt=0 => a_bar=1, bx=0: identity steps
        x32 = jnp.pad(x32, p2)
        bmat = jnp.pad(bmat, p2)
        c = jnp.pad(c, p2)

    def to_chunks(t):
        return jnp.moveaxis(t.reshape((bsz, nchunks, chunk) + t.shape[2:]), 1, 0)

    @jax.checkpoint  # per-chunk remat: bwd recomputes the (B,L,d,N)
    def body(h, xs):  # intermediates chunk-by-chunk instead of saving all
        dtj, xj, bj, cj = xs                          # (B, L, *) small
        a_bar = jnp.exp(dtj[..., None] * a)           # (B, L, d, N)
        bx = (dtj * xj)[..., None] * bj[..., None, :]
        bx = bx.at[:, 0].add(a_bar[:, 0] * h)
        _, hh = jax.lax.associative_scan(_assoc_op, (a_bar, bx), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", hh, cj)
        return hh[:, -1], y

    h_last, ys = jax.lax.scan(
        body, h0, (to_chunks(dt32), to_chunks(x32), to_chunks(bmat), to_chunks(c))
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nchunks * chunk, -1)
    return y[:, :s], h_last


def mamba_specs(cfg) -> dict:
    m = cfg.ssm
    d = cfg.d_model
    di = m.expand * d
    dtr = m.dt_rank or -(-d // 16)
    return {
        "in_proj": L.linear_specs(d, 2 * di),
        "conv": L.causal_conv_specs(di, m.conv_dim),
        "x_proj": L.linear_specs(di, dtr + 2 * m.state_dim),
        "dt_proj": L.linear_specs(dtr, di, bias=True),
        "A_log": L.P((di, m.state_dim), "normal", 0.5),
        "D": L.P((di,), "ones"),
        "out_proj": L.linear_specs(di, d),
    }


def _mamba_core(p, xz: Array, cfg, conv_state, ssm_state, *, chunk):
    """Shared seq/step Mamba math. xz: (B, S, 2*di)."""
    m = cfg.ssm
    di = m.expand * cfg.d_model
    dtr = m.dt_rank or -(-cfg.d_model // 16)
    x, z = jnp.split(xz, 2, axis=-1)
    x, conv_state = L.causal_conv1d(p["conv"], x, conv_state)
    x = jax.nn.silu(x)

    proj = L.linear(p["x_proj"], x)                    # (B,S,dtr+2N)
    dt, bmat, cmat = jnp.split(proj, [dtr, dtr + m.state_dim], axis=-1)
    dt = jax.nn.softplus(L.linear(p["dt_proj"], dt))   # (B,S,di)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))       # (di,N)

    # fused chunked scan: discretization (a_bar = exp(dt*A), b_bar = dt*B*x),
    # recurrence, and the <c, h> readout all happen per chunk — no
    # (B, S, d_inner, N) tensor is ever materialized
    y, h_last = chunked_ssm_outputs(
        dt.astype(jnp.float32),
        x.astype(jnp.float32),
        a,
        bmat.astype(jnp.float32),
        cmat.astype(jnp.float32),
        ssm_state,
        chunk,
    )
    y = (y + x.astype(jnp.float32) * p["D"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y, conv_state, h_last


def mamba(p, x: Array, cfg, state: dict | None = None, mode: str = "train"):
    """x: (B, S, d). state: {"conv": (B,W-1,di), "ssm": (B,di,N)} or None."""
    m = cfg.ssm
    b = x.shape[0]
    di = m.expand * cfg.d_model
    if state is None:
        conv_state = None
        ssm_state = jnp.zeros((b, di, m.state_dim), jnp.float32)
    else:
        conv_state, ssm_state = state["conv"], state["ssm"]
    xz = L.linear(p["in_proj"], x)
    y, conv_state, ssm_state = _mamba_core(
        p, xz, cfg, conv_state, ssm_state, chunk=m.chunk
    )
    out = L.linear(p["out_proj"], y)
    new_state = {"conv": conv_state, "ssm": ssm_state}
    return out, new_state


def mamba_init_state(cfg, batch: int, dtype):
    m = cfg.ssm
    di = m.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, m.conv_dim - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, m.state_dim), jnp.float32),
    }


def mamba_abstract_state(cfg, batch: int, dtype):
    m = cfg.ssm
    di = m.expand * cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, m.conv_dim - 1, di), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, di, m.state_dim), jnp.float32),
    }


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, chunk-parallelizable) and sLSTM (scalar
# memory with recurrent weights, sequential) — arXiv:2405.04517
# ---------------------------------------------------------------------------


def mlstm_zero_state(b: int, nh: int, hd: int) -> dict:
    return {
        "c": jnp.zeros((b, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((b, nh, hd), jnp.float32),
        "m": jnp.full((b, nh), -1e30, jnp.float32),
    }


def mlstm_chunkwise(q, k, v, i_pre, logf, state, chunk: int):
    """Chunkwise-parallel stabilized mLSTM (matrix memory).

    q,k,v: (B,S,nh,hd); i_pre/logf: (B,S,nh) log-domain gates.
    state: {"c": (B,nh,hd,hd), "n": (B,nh,hd), "m": (B,nh)} where c,n are
    stored *stabilized* (true C = c * exp(m)).

    The TPU-native form (DESIGN.md §4): per chunk, the output splits into an
    inter-chunk term (decayed boundary state) and an intra-chunk term
    (attention-like (L,L) matmul), so per-step (hd,hd) outer products are
    never materialized along the sequence.
    """
    b, s, nh, hd = q.shape
    chunk = max(min(chunk, s), 1)
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        i_pre = jnp.pad(
            i_pre, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30
        )
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(t):
        return jnp.moveaxis(
            t.reshape((b, nchunks, chunk) + t.shape[2:]), 1, 0
        )

    qc, kc, vc = to_chunks(q.astype(jnp.float32)), to_chunks(k.astype(jnp.float32)), to_chunks(v.astype(jnp.float32))
    ic, fc = to_chunks(i_pre), to_chunks(logf)

    def body(carry, xs):
        c0, n0, m0 = carry                       # stabilized: C = c0 e^{m0}
        qj, kj, vj, ij, fj = xs                  # (B,L,nh,*)
        cum = jnp.cumsum(fj, axis=1)             # (B,L,nh): sum_{u<=j} logf_u
        # running max of (logi_i - cum_i) over i<=j
        g = jax.lax.associative_scan(jnp.maximum, ij - cum, axis=1)
        m_all = cum + jnp.maximum(m0[:, None], g)           # (B,L,nh)
        # inter-chunk: exp(cum_j + m0 - m_j) * q_j C_0
        inter_w = jnp.exp(cum + m0[:, None] - m_all)        # (B,L,nh)
        h_inter = jnp.einsum("blnd,bnde->blne", qj, c0) * inter_w[..., None]
        n_inter = n0[:, None] * inter_w[..., None]          # (B,L,nh,hd)
        # intra-chunk: scores[j,i] = exp(cum_j - cum_i + logi_i - m_j) q_j.k_i
        logw = (
            cum[:, :, None] - cum[:, None, :] + ij[:, None, :]
            - m_all[:, :, None]
        )                                                   # (B,Lq,Lk,nh)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        # mask BEFORE exp: future-position logw can overflow, and
        # where(mask, exp(inf), 0) still propagates NaN gradients
        logw = jnp.where(mask[None, :, :, None], logw, -1e30)
        w_intra = jnp.exp(jnp.minimum(logw, 60.0))
        scores = jnp.einsum("blnd,bind->blin", qj, kj) * w_intra
        h_intra = jnp.einsum("blin,bind->blnd", scores, vj)
        n_intra = jnp.einsum("blin,bind->blnd", w_intra, kj)
        num = h_inter + h_intra
        n_all = n_inter + n_intra
        den = jnp.maximum(
            jnp.abs(jnp.einsum("blnd,blnd->bln", n_all, qj)), jnp.exp(-m_all)
        )
        h = num / den[..., None]
        # carry update (stabilized at m_last)
        m_last = m_all[:, -1]
        cum_l = cum[:, -1]                                   # (B,nh)
        wc = jnp.exp(cum_l + m0 - m_last)
        wi = jnp.exp(cum_l[:, None] - cum + ij - m_last[:, None])  # (B,L,nh)
        c_new = c0 * wc[..., None, None] + jnp.einsum(
            "blnd,blne->bnde", kj * wi[..., None], vj
        )
        n_new = n0 * wc[..., None] + jnp.einsum("blnd,bln->bnd", kj, wi)
        return (c_new, n_new, m_last), h

    (c, n, m), hs = jax.lax.scan(
        body, (state["c"], state["n"], state["m"]), (qc, kc, vc, ic, fc)
    )
    h = jnp.moveaxis(hs, 0, 1).reshape(b, nchunks * chunk, nh, hd)[:, :s]
    return h, {"c": c, "n": n, "m": m}


def mlstm_step(q, k, v, i_pre, logf, state):
    """Single-token recurrent mLSTM update (decode). q/k/v: (B,1,nh,hd)."""
    qj, kj, vj = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    ip, lf = i_pre[:, 0], logf[:, 0]                     # (B,nh)
    c0, n0, m0 = state["c"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m0, ip)
    fg = jnp.exp(lf + m0 - m_new)[..., None]
    ig = jnp.exp(ip - m_new)[..., None]
    c = c0 * fg[..., None] + (ig * kj)[..., :, None] * vj[..., None, :]
    n = n0 * fg + ig * kj
    den = jnp.maximum(jnp.abs(jnp.sum(n * qj, -1)), jnp.exp(-m_new))
    h = jnp.einsum("bnde,bnd->bne", c, qj) / den[..., None]
    return h[:, None], {"c": c, "n": n, "m": m_new}


def mlstm_specs(cfg) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    di = 2 * d                       # up-projection factor 2
    return {
        "norm": L.rmsnorm_specs(d),
        "up": L.linear_specs(d, 2 * di),
        "conv": L.causal_conv_specs(di, 4),
        "wq": L.linear_specs(di, di),
        "wk": L.linear_specs(di, di),
        "wv": L.linear_specs(di, di),
        "wi": L.linear_specs(di, nh, bias=True),
        "wf": L.linear_specs(di, nh, bias=True),
        "out_norm": L.rmsnorm_specs(di),
        "down": L.linear_specs(di, d),
    }


def mlstm_block(p, x: Array, cfg, state=None, mode: str = "train"):
    """Pre-norm residual mLSTM block. x: (B,S,d)."""
    d = cfg.d_model
    nh = cfg.num_heads
    di = 2 * d
    hd = di // nh
    b, s, _ = x.shape
    chunk = (cfg.ssm.chunk if cfg.ssm else 256)

    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    up = L.linear(p["up"], h)
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xc, conv_state = L.causal_conv1d(p["conv"], xm, conv_state)
    xc = jax.nn.silu(xc)

    q = L.linear(p["wq"], xc).reshape(b, s, nh, hd)
    k = L.linear(p["wk"], xc).reshape(b, s, nh, hd) * (hd**-0.5)
    v = L.linear(p["wv"], xm).reshape(b, s, nh, hd)
    # exponential gating with log-domain stabilization
    i_pre = L.linear(p["wi"], xc).astype(jnp.float32)      # (B,S,nh)
    f_pre = L.linear(p["wf"], xc).astype(jnp.float32)

    logf = -jax.nn.softplus(-f_pre)                        # log sigmoid(f_pre)
    if state is None:
        mstate = mlstm_zero_state(b, nh, hd)
    else:
        mstate = {k_: state[k_] for k_ in ("c", "n", "m")}
    if mode == "decode":
        hout, mstate = mlstm_step(q, k, v, i_pre, logf, mstate)
    else:
        hout, mstate = mlstm_chunkwise(q, k, v, i_pre, logf, mstate, chunk)
    c_last, n_last, m_last = mstate["c"], mstate["n"], mstate["m"]
    hout = hout.reshape(b, s, di).astype(x.dtype)
    hout = L.rmsnorm(p["out_norm"], hout, cfg.norm_eps)
    out = L.linear(p["down"], hout * jax.nn.silu(z))
    new_state = {"conv": conv_state, "c": c_last, "n": n_last, "m": m_last}
    return x + out, new_state


def mlstm_init_state(cfg, batch: int, dtype):
    d, nh = cfg.d_model, cfg.num_heads
    di = 2 * d
    hd = di // nh
    return dict(
        conv=jnp.zeros((batch, 3, di), dtype), **mlstm_zero_state(batch, nh, hd)
    )


def slstm_specs(cfg) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    return {
        "norm": L.rmsnorm_specs(d),
        "wz": L.linear_specs(d, d, bias=True),
        "wi": L.linear_specs(d, d, bias=True),
        "wf": L.linear_specs(d, d, bias=True),
        "wo": L.linear_specs(d, d, bias=True),
        # block-diagonal recurrent weights, one (hd, hd) block per head
        "rz": L.P((nh, hd, hd), "normal", 0.02),
        "ri": L.P((nh, hd, hd), "normal", 0.02),
        "rf": L.P((nh, hd, hd), "normal", 0.02),
        "ro": L.P((nh, hd, hd), "normal", 0.02),
        "out_norm": L.rmsnorm_specs(d),
        "down": L.linear_specs(d, d),
    }


def slstm_block(p, x: Array, cfg, state=None, mode: str = "train"):
    """sLSTM block: sequential time scan (recurrent weights). x: (B,S,d)."""
    d, nh = cfg.d_model, cfg.num_heads
    hd = d // nh
    b, s, _ = x.shape

    xn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    pre = {
        g: L.linear(p["w" + g], xn).astype(jnp.float32).reshape(b, s, nh, hd)
        for g in ("z", "i", "f", "o")
    }
    if state is None:
        h0 = jnp.zeros((b, nh, hd), jnp.float32)
        c0 = jnp.zeros((b, nh, hd), jnp.float32)
        n0 = jnp.ones((b, nh, hd), jnp.float32)
        m0 = jnp.zeros((b, nh, hd), jnp.float32)
    else:
        h0, c0, n0, m0 = state["h"], state["c"], state["n"], state["m"]

    rz = p["rz"].astype(jnp.float32)
    ri = p["ri"].astype(jnp.float32)
    rf = p["rf"].astype(jnp.float32)
    ro = p["ro"].astype(jnp.float32)

    def step(carry, xs):
        h, c, n, m = carry
        pz, pi, pf, po = xs
        rec = lambda r: jnp.einsum("bnj,nij->bni", h, r)
        z = jnp.tanh(pz + rec(rz))
        i_pre = pi + rec(ri)
        f_pre = pf + rec(rf)
        o = jax.nn.sigmoid(po + rec(ro))
        logf = -jax.nn.softplus(-f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        i_g = jnp.exp(i_pre - m_new)
        f_g = jnp.exp(logf + m - m_new)
        c = f_g * c + i_g * z
        n = f_g * n + i_g
        h = o * c / jnp.maximum(n, 1.0)
        return (h, c, n, m_new), h

    xs = tuple(jnp.moveaxis(pre[g], 1, 0) for g in ("z", "i", "f", "o"))
    (h_l, c_l, n_l, m_l), hs = jax.lax.scan(step, (h0, c0, n0, m0), xs)
    hout = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    hout = L.rmsnorm(p["out_norm"], hout, cfg.norm_eps)
    out = L.linear(p["down"], hout)
    new_state = {"h": h_l, "c": c_l, "n": n_l, "m": m_l}
    return x + out, new_state


def slstm_init_state(cfg, batch: int, dtype):
    d, nh = cfg.d_model, cfg.num_heads
    hd = d // nh
    z = lambda: jnp.zeros((batch, nh, hd), jnp.float32)
    return {"h": z(), "c": z(), "n": jnp.ones((batch, nh, hd), jnp.float32), "m": z()}
