"""Block registry: every architecture family is a stack of these blocks.

A block is (specs, apply, init_cache, abstract_cache) with a uniform apply
signature so homogeneous segments can ``lax.scan`` over stacked params:

    apply(p, x, cache, ctx) -> (x, new_cache, aux)

``ctx`` is a :class:`BlockCtx` of static-ish values (mode, window override,
decode position, encoder states).  ``aux`` is a fixed-schema dict of scalars
(MoE losses) so scans stay homogeneous.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BlockCtx:
    mode: str                      # train | prefill | decode
    pos: Any = None                # decode position (traced scalar)
    causal: bool = True            # False for diffusion-LM denoising
    window_override: int = -1      # -1: use block default; 0: full; >0: window
    protected: int = 0             # cache slots never evicted (meta tokens)
    enc_out: Any = None            # whisper encoder states (B, F, d)
    lengths: Any = None            # (B,) valid seq lengths of a right-padded
                                   # batch (diffusion-LM mixed-seq-len path);
                                   # attention blocks mask pad keys


def zero_aux() -> dict:
    return {"moe_aux": jnp.float32(0.0), "moe_z": jnp.float32(0.0)}


def _window(cfg, ctx: BlockCtx, default: int) -> int:
    return default if ctx.window_override < 0 else ctx.window_override


# ---------------------------------------------------------------------------
# dense (llama/qwen/deepseek-67b/minitron/paligemma) and moe (mixtral)
# ---------------------------------------------------------------------------


def dense_specs(cfg) -> dict:
    return {
        "ln1": L.rmsnorm_specs(cfg.d_model),
        "attn": A.attention_specs(cfg),
        "ln2": L.rmsnorm_specs(cfg.d_model),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def dense_apply(p, x, cache, ctx: BlockCtx, cfg):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, cache = A.attention(
        p["attn"], h, cfg,
        mode=ctx.mode, cache=cache, pos=ctx.pos,
        window=_window(cfg, ctx, cfg.sliding_window),
        protected=ctx.protected, causal=ctx.causal, lengths=ctx.lengths,
    )
    x = x + attn_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h, cfg.mlp_act)
    return x, cache, zero_aux()


def moe_specs_(cfg) -> dict:
    return {
        "ln1": L.rmsnorm_specs(cfg.d_model),
        "attn": A.attention_specs(cfg),
        "ln2": L.rmsnorm_specs(cfg.d_model),
        "moe": MOE.moe_specs(cfg),
    }


def moe_apply(p, x, cache, ctx: BlockCtx, cfg):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, cache = A.attention(
        p["attn"], h, cfg,
        mode=ctx.mode, cache=cache, pos=ctx.pos,
        window=_window(cfg, ctx, cfg.sliding_window),
        protected=ctx.protected, causal=ctx.causal, lengths=ctx.lengths,
    )
    x = x + attn_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    ffn_out, aux = MOE.moe_ffn(p["moe"], h, cfg)
    return x + ffn_out, cache, {**zero_aux(), **aux}


# ---------------------------------------------------------------------------
# mla_moe (deepseek-v2-lite)
# ---------------------------------------------------------------------------


def mla_moe_specs(cfg) -> dict:
    return {
        "ln1": L.rmsnorm_specs(cfg.d_model),
        "mla": MLA.mla_specs(cfg),
        "ln2": L.rmsnorm_specs(cfg.d_model),
        "moe": MOE.moe_specs(cfg),
    }


def mla_moe_apply(p, x, cache, ctx: BlockCtx, cfg):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if ctx.mode == "decode":
        attn_out, cache = MLA.mla_decode(p["mla"], h, cfg, cache, ctx.pos)
    else:
        attn_out, cache = MLA.mla_train(
            p["mla"], h, cfg, ctx.mode, cache, lengths=ctx.lengths
        )
    x = x + attn_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    ffn_out, aux = MOE.moe_ffn(p["moe"], h, cfg)
    return x + ffn_out, cache, {**zero_aux(), **aux}


# ---------------------------------------------------------------------------
# xLSTM blocks (pre-norm residual handled inside SSM module)
# ---------------------------------------------------------------------------


def mlstm_apply(p, x, cache, ctx: BlockCtx, cfg):
    mode = ctx.mode
    x, state = SSM.mlstm_block(p, x, cfg, state=cache, mode=mode)
    return x, state if cache is not None else None, zero_aux()


def slstm_apply(p, x, cache, ctx: BlockCtx, cfg):
    x, state = SSM.slstm_block(p, x, cfg, state=cache, mode=ctx.mode)
    return x, state if cache is not None else None, zero_aux()


# ---------------------------------------------------------------------------
# hymba: parallel attention + mamba heads, then MLP
# ---------------------------------------------------------------------------


def hymba_specs(cfg) -> dict:
    return {
        "ln1": L.rmsnorm_specs(cfg.d_model),
        "attn": A.attention_specs(cfg),
        "mamba": SSM.mamba_specs(cfg),
        "attn_norm": L.rmsnorm_specs(cfg.d_model),
        "mamba_norm": L.rmsnorm_specs(cfg.d_model),
        "ln2": L.rmsnorm_specs(cfg.d_model),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def _hymba_apply(p, x, cache, ctx: BlockCtx, cfg, window: int):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_cache = None if cache is None else cache["attn"]
    ssm_state = None if cache is None else cache["ssm"]
    attn_out, attn_cache = A.attention(
        p["attn"], h, cfg,
        mode=ctx.mode, cache=attn_cache, pos=ctx.pos,
        window=_window(cfg, ctx, window), protected=ctx.protected,
        causal=ctx.causal, lengths=ctx.lengths,
    )
    mamba_out, ssm_state = SSM.mamba(
        p["mamba"], h, cfg,
        state=ssm_state if cache is not None else None, mode=ctx.mode,
    )
    # Hymba fuses the two head groups by averaging their normalized outputs
    fused = 0.5 * (
        L.rmsnorm(p["attn_norm"], attn_out, cfg.norm_eps)
        + L.rmsnorm(p["mamba_norm"], mamba_out, cfg.norm_eps)
    )
    x = x + fused
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h, cfg.mlp_act)
    new_cache = None if cache is None else {"attn": attn_cache, "ssm": ssm_state}
    return x, new_cache, zero_aux()


def hymba_swa_apply(p, x, cache, ctx, cfg):
    return _hymba_apply(p, x, cache, ctx, cfg, cfg.sliding_window)


def hymba_full_apply(p, x, cache, ctx, cfg):
    return _hymba_apply(p, x, cache, ctx, cfg, 0)


# ---------------------------------------------------------------------------
# whisper: encoder block (bidirectional) and decoder block (self + cross)
# ---------------------------------------------------------------------------


def enc_specs(cfg) -> dict:
    return {
        "ln1": L.layernorm_specs(cfg.d_model),
        "attn": A.attention_specs(cfg),
        "ln2": L.layernorm_specs(cfg.d_model),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, "gelu_plain"),
    }


def enc_apply(p, x, cache, ctx: BlockCtx, cfg):
    h = L.layernorm(p["ln1"], x, cfg.norm_eps)
    attn_out, _ = A.attention(
        p["attn"], h, cfg, mode="train", cache=None, causal=False,
        lengths=ctx.lengths,
    )
    x = x + attn_out
    h = L.layernorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h, "gelu_plain")
    return x, cache, zero_aux()


def xdec_specs(cfg) -> dict:
    return {
        "ln1": L.layernorm_specs(cfg.d_model),
        "self_attn": A.attention_specs(cfg),
        "ln_x": L.layernorm_specs(cfg.d_model),
        "cross_attn": A.attention_specs(cfg),
        "ln2": L.layernorm_specs(cfg.d_model),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, "gelu_plain"),
    }


def xdec_apply(p, x, cache, ctx: BlockCtx, cfg):
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    self_cache = None if cache is None else cache["self"]
    h = L.layernorm(p["ln1"], x, cfg.norm_eps)
    attn_out, self_cache = A.attention(
        p["self_attn"], h, cfg,
        mode=ctx.mode, cache=self_cache, pos=ctx.pos,
        window=_window(cfg, ctx, cfg.sliding_window),
        lengths=ctx.lengths,
    )
    x = x + attn_out

    ek = ev = None
    if ctx.mode == "decode":
        ek, ev = cache["xk"], cache["xv"]
    elif ctx.enc_out is not None:
        enc = ctx.enc_out
        b, f, _ = enc.shape
        ek = L.linear(p["cross_attn"]["wk"], enc).reshape(b, f, kvh, hd)
        ev = L.linear(p["cross_attn"]["wv"], enc).reshape(b, f, kvh, hd)
    if ek is not None:  # no encoder context => decoder-only (diffusion-LM)
        h = L.layernorm(p["ln_x"], x, cfg.norm_eps)
        xo, _ = A.attention(
            p["cross_attn"], h, cfg, mode=ctx.mode, pos=ctx.pos, cross_kv=(ek, ev)
        )
        x = x + xo

    h = L.layernorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h, "gelu_plain")

    new_cache = cache
    if cache is not None:
        new_cache = dict(cache, self=self_cache)
        if ctx.mode == "prefill":
            new_cache["xk"], new_cache["xv"] = ek, ev
    return x, new_cache, zero_aux()


# ---------------------------------------------------------------------------
# cache factories
# ---------------------------------------------------------------------------


def _attn_cache(cfg, batch, slots, dtype, abstract):
    fn = A.abstract_cache if abstract else A.init_cache
    return fn(
        batch, slots, cfg.num_kv_heads, cfg.resolved_head_dim, dtype,
        quant=(cfg.kv_quant == "int8"),
    )


def _mla_cache(cfg, batch, slots, dtype, abstract):
    fn = MLA.mla_abstract_cache if abstract else MLA.mla_init_cache
    return fn(cfg, batch, slots, dtype)


def _ssm_cache(cfg, batch, slots, dtype, abstract):
    if abstract:
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            SSM.mamba_init_state(cfg, batch, dtype),
        )
    return SSM.mamba_init_state(cfg, batch, dtype)


def _mlstm_cache(cfg, batch, slots, dtype, abstract):
    st = SSM.mlstm_init_state(cfg, batch, dtype)
    if abstract:
        return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    return st


def _slstm_cache(cfg, batch, slots, dtype, abstract):
    st = SSM.slstm_init_state(cfg, batch, dtype)
    if abstract:
        return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    return st


def _hymba_cache(cfg, batch, slots, dtype, abstract):
    return {
        "attn": _attn_cache(cfg, batch, slots, dtype, abstract),
        "ssm": _ssm_cache(cfg, batch, slots, dtype, abstract),
    }


def _xdec_cache(cfg, batch, slots, dtype, abstract):
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    f = cfg.frontend.num_positions
    shape = (batch, f, kvh, hd)
    if abstract:
        xk = xv = jax.ShapeDtypeStruct(shape, dtype)
    else:
        xk = jnp.zeros(shape, dtype)
        xv = jnp.zeros(shape, dtype)
    return {
        "self": _attn_cache(cfg, batch, slots, dtype, abstract),
        "xk": xk,
        "xv": xv if abstract else jnp.zeros(shape, dtype),
    }


@dataclasses.dataclass(frozen=True)
class BlockDef:
    specs: Callable
    apply: Callable
    cache: Callable | None  # (cfg, batch, slots, dtype, abstract) -> pytree


BLOCKS: dict[str, BlockDef] = {
    "dense": BlockDef(dense_specs, dense_apply, _attn_cache),
    "moe": BlockDef(moe_specs_, moe_apply, _attn_cache),
    "mla_moe": BlockDef(mla_moe_specs, mla_moe_apply, _mla_cache),
    "mlstm": BlockDef(SSM.mlstm_specs, mlstm_apply, _mlstm_cache),
    "slstm": BlockDef(SSM.slstm_specs, slstm_apply, _slstm_cache),
    "hymba_swa": BlockDef(hymba_specs, hymba_swa_apply, _hymba_cache),
    "hymba_full": BlockDef(hymba_specs, hymba_full_apply, _hymba_cache),
    "enc": BlockDef(enc_specs, enc_apply, None),
    "xdec": BlockDef(xdec_specs, xdec_apply, _xdec_cache),
}
