"""Common layers + the parameter-spec system.

Parameters are plain pytrees (nested dicts of arrays).  Each layer exposes a
``*_specs`` function returning a matching pytree of :class:`P` (shape +
initializer), from which we derive either real initialized params (smoke
tests, training) or ``jax.ShapeDtypeStruct`` stand-ins (the multi-pod
dry-run, which must never allocate).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class P:
    """Parameter spec: shape + init rule. fan_in for scaled-normal init."""

    shape: tuple[int, ...]
    init: str = "fan_in"  # fan_in | zeros | ones | normal | embed
    scale: float = 1.0

    def initialize(self, key: jax.Array, dtype) -> Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "normal":
            return self.scale * jax.random.normal(key, self.shape, dtype)
        if self.init == "embed":
            return jax.random.normal(key, self.shape, dtype) * 0.02 * self.scale
        if self.init == "fan_in":
            fan_in = self.shape[0] if len(self.shape) >= 2 else 1
            std = self.scale / math.sqrt(max(fan_in, 1))
            return jax.random.normal(key, self.shape, dtype) * std
        raise ValueError(f"unknown init {self.init!r}")


def is_spec(x) -> bool:
    return isinstance(x, P)


def init_params(specs, key: jax.Array, dtype=jnp.float32):
    """Initialize a pytree of P into a pytree of arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [s.initialize(k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(specs, dtype=jnp.float32):
    """P pytree -> ShapeDtypeStruct pytree (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=is_spec
    )


def stack_specs(specs, n: int):
    """Prepend a layer dimension of size n to every spec (for lax.scan)."""
    return jax.tree.map(
        lambda s: P((n,) + s.shape, s.init, s.scale), specs, is_leaf=is_spec
    )


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------


def rmsnorm_specs(d: int) -> dict:
    return {"scale": P((d,), "ones")}


def rmsnorm(p, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_specs(d: int) -> dict:
    return {"scale": P((d,), "ones"), "bias": P((d,), "zeros")}


def layernorm(p, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Linear / MLP
# ---------------------------------------------------------------------------


def linear_specs(d_in: int, d_out: int, bias: bool = False, scale=1.0) -> dict:
    s = {"w": P((d_in, d_out), "fan_in", scale)}
    if bias:
        s["b"] = P((d_out,), "zeros")
    return s


def linear(p, x: Array) -> Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def mlp_specs(d: int, d_ff: int, act: str = "silu") -> dict:
    if act in ("silu", "gelu"):  # gated
        return {
            "wi": linear_specs(d, d_ff),
            "wg": linear_specs(d, d_ff),
            "wo": linear_specs(d_ff, d, scale=1.0),
        }
    return {"wi": linear_specs(d, d_ff), "wo": linear_specs(d_ff, d)}


def mlp(p, x: Array, act: str = "silu") -> Array:
    if act == "silu":
        h = jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x)
    elif act == "gelu":
        h = jax.nn.gelu(linear(p["wg"], x)) * linear(p["wi"], x)
    else:  # gelu_plain
        h = jax.nn.gelu(linear(p["wi"], x))
    return linear(p["wo"], h)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Time embedding (diffusion conditioning)
# ---------------------------------------------------------------------------


def sinusoidal_time_embed(t: Array, dim: int, max_period: float = 1e4) -> Array:
    """t: scalar or (B,) in [0, 1] -> (B?, dim) embedding."""
    t = jnp.asarray(t, jnp.float32) * 1000.0  # scale to DDPM-like range
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half) / half)
    ang = t[..., None] * freqs
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def time_mlp_specs(d_model: int, d_time: int = 256) -> dict:
    return {
        "w1": linear_specs(d_time, d_model, bias=True),
        "w2": linear_specs(d_model, d_model, bias=True),
    }


def time_mlp(p, t: Array, d_time: int = 256) -> Array:
    h = sinusoidal_time_embed(t, d_time)
    h = jax.nn.silu(linear(p["w1"], h))
    return linear(p["w2"], h)


# ---------------------------------------------------------------------------
# Causal depthwise conv (Mamba / xLSTM front conv)
# ---------------------------------------------------------------------------


def causal_conv_specs(d: int, width: int) -> dict:
    return {"w": P((width, d), "normal", 0.1), "b": P((d,), "zeros")}


def causal_conv1d(p, x: Array, state: Array | None = None):
    """Depthwise causal conv. x: (B, S, d).

    Returns (y, new_state) where state is the last (width-1) inputs — the
    decode-time carry.
    """
    w = p["w"].astype(x.dtype)  # (W, d)
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S + W - 1, d)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i] for i in range(width)
    ) + p["b"].astype(x.dtype)
    new_state = xp[:, -(width - 1) :, :] if width > 1 else pad
    return y, new_state
