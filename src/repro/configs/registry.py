"""Architecture registry + the four assigned input shapes.

Every entry cites its source (model card / paper) and matches the assigned
specification exactly.  ``get_config(name)`` returns the full config;
``get_config(name, smoke=True)`` the reduced same-family variant used by the
CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig

_ARCHS = [
    "llama3_2_1b",
    "qwen2_1_5b",
    "whisper_base",
    "deepseek_v2_lite",
    "xlstm_350m",
    "mixtral_8x7b",
    "deepseek_67b",
    "hymba_1_5b",
    "paligemma_3b",
    "minitron_4b",
]

# public names (assignment ids) -> module names
ALIASES = {
    "llama3.2-1b": "llama3_2_1b",
    "qwen2-1.5b": "qwen2_1_5b",
    "whisper-base": "whisper_base",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "xlstm-350m": "xlstm_350m",
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-67b": "deepseek_67b",
    "hymba-1.5b": "hymba_1_5b",
    "paligemma-3b": "paligemma_3b",
    "minitron-4b": "minitron_4b",
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def arch_names() -> list[str]:
    return sorted(ALIASES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in _ARCHS:
        raise ValueError(f"unknown architecture {name!r}; known: {arch_names()}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.smoke() if smoke else cfg


def long_context_policy(cfg: ModelConfig) -> str:
    """How this arch runs long_500k (DESIGN.md shape/skip policy).

    'native'  — sub-quadratic by construction (SSM / hybrid / native SWA)
    'swa'     — dense arch served with the sliding-window variant
    """
    if cfg.family in ("ssm", "hybrid"):
        return "native"
    if cfg.sliding_window:
        return "native"
    return "swa"
