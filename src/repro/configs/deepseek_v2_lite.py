"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

MLA kv_lora_rank=512; 64 routed experts (top-6) + 2 shared experts,
expert d_ff=1408.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    rope_theta=1e4,
    mlp_act="silu",
    stack_pattern=(("mla_moe", 27),),
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2),
    source="arXiv:2405.04434",
)
