"""xlstm-350m — sLSTM + mLSTM block stack [arXiv:2405.04517].

24 blocks at the paper's 7:1 mLSTM:sLSTM ratio -> (7m, 1s) x 3.
d_ff=0: xLSTM blocks carry their own gated up/down projections.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    stack_pattern=(
        ("mlstm", 7), ("slstm", 1),
        ("mlstm", 7), ("slstm", 1),
        ("mlstm", 7), ("slstm", 1),
    ),
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
