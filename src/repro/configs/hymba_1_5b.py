"""hymba-1.5b — hybrid: parallel attention + Mamba heads per layer, meta
tokens, mostly-SWA with 3 full-attention layers [arXiv:2411.13676]."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    sliding_window=1024,
    num_meta_tokens=128,
    mlp_act="silu",
    stack_pattern=(
        ("hymba_full", 1), ("hymba_swa", 14),
        ("hymba_full", 1), ("hymba_swa", 15),
        ("hymba_full", 1),
    ),
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2, chunk=256),
    source="arXiv:2411.13676",
)
