"""minitron-4b — width-pruned Nemotron-4 dense decoder [arXiv:2407.14679]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    head_dim=128,
    rope_theta=1e4,
    mlp_act="silu",
    source="arXiv:2407.14679",
)
