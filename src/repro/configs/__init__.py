from repro.configs.base import (
    FrontendStub,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)
from repro.configs.registry import (
    ALIASES,
    INPUT_SHAPES,
    InputShape,
    arch_names,
    get_config,
    long_context_policy,
)
