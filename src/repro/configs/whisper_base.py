"""whisper-base — encoder-decoder audio backbone [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is the assignment's frontend
STUB: input_specs() delivers (B, 1500, 512) frame embeddings; the 6-layer
encoder transformer and 6-layer decoder are implemented here.
"""

from repro.configs.base import FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    num_encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    use_rope=False,                     # learned decoder positions
    mlp_act="gelu_plain",
    stack_pattern=(("xdec", 6),),
    frontend=FrontendStub(kind="audio", num_positions=1500, feature_dim=512),
    max_position=524288,                # decoder position table (long variant)
    source="arXiv:2212.04356",
)
