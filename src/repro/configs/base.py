"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
model zoo in :mod:`repro.models` builds forward functions from it.  Configs
are frozen dataclasses so they hash (jit static args) and diff cleanly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0            # always-active shared experts (DeepSeek)
    capacity_factor: float = 1.25
    dispatch_group: int = 4096     # tokens per capacity group (§Perf: caps
                                   # the (E, C, d) dispatch buffer size)
    router_z_loss: float = 1e-3
    aux_loss_weight: float = 1e-2
    # dispatch strategy: "dropping" (scatter, default), "dense_mix"
    # (all-experts reference, smoke/oracle only), "expert_parallel"
    # (shard_map all-to-all — perf path)
    dispatch: str = "dropping"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 => full-rank q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM (Hymba heads) / xLSTM cells."""

    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2                # d_inner = expand * d_model
    dt_rank: int = 0               # 0 => ceil(d_model / 16)
    chunk: int = 256               # chunked-scan length (TPU adaptation)


@dataclasses.dataclass(frozen=True)
class FrontendStub:
    """Modality frontend carve-out: precomputed embeddings of this shape."""

    kind: str                      # "audio" | "vision"
    num_positions: int             # frames or patches
    feature_dim: int               # embedding dim delivered to the backbone


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # ---- attention ----
    head_dim: int = 0              # 0 => d_model // num_heads
    qkv_bias: bool = False         # Qwen2
    rope_theta: float = 1e4
    use_rope: bool = True          # Whisper decoder uses learned pos emb
    max_position: int = 32768
    sliding_window: int = 0        # 0 => full attention (Mixtral: 4096)
    long_context_window: int = 8192  # window used for the long_500k variant
    attn_logit_softcap: float = 0.0
    # ---- blocks ----
    # stack pattern: tuple of (block_type, count) segments; empty => derived
    stack_pattern: tuple[tuple[str, int], ...] = ()
    mlp_act: str = "silu"          # silu (swiglu) | gelu (geglu) | gelu_plain
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # ---- substructures ----
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    frontend: FrontendStub | None = None
    num_meta_tokens: int = 0       # Hymba learnable prefix tokens
    # ---- encoder-decoder ----
    num_encoder_layers: int = 0    # Whisper
    # ---- numerics / system ----
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    vocab_pad_multiple: int = 2048  # pad vocab so it shards over model axis
    kv_quant: str = "none"         # none | int8 (decode cache quantization)
    attention_impl: str = "auto"   # auto | naive | chunked | pallas
    attn_chunk: int = 1024
    # source citation for the assigned-architecture pool
    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return int(math.ceil(self.vocab_size / m) * m)

    @property
    def blocks(self) -> tuple[tuple[str, int], ...]:
        if self.stack_pattern:
            return self.stack_pattern
        default = {
            "dense": "dense",
            "moe": "moe",
            "vlm": "dense",
            "audio": "dense",
        }.get(self.family)
        if default is None:
            raise ValueError(
                f"{self.name}: family {self.family!r} needs an explicit "
                "stack_pattern"
            )
        return ((default, self.num_layers),)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Reduced variant for CPU smoke tests (same family, tiny dims).
    def smoke(self) -> "ModelConfig":
        kw: dict[str, Any] = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            vocab_pad_multiple=64,
            max_position=512,
            head_dim=min(self.resolved_head_dim, 32),
            dtype=jnp.float32,
            remat=False,
            num_meta_tokens=min(self.num_meta_tokens, 8),
            num_encoder_layers=min(self.num_encoder_layers, 2),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            long_context_window=64,
            attn_chunk=64,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 128),
                num_shared=min(self.moe.num_shared, 1),
            )
        if self.mla:
            kw["mla"] = dataclasses.replace(
                self.mla,
                kv_lora_rank=64,
                qk_nope_head_dim=32,
                qk_rope_head_dim=16,
                v_head_dim=32,
            )
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, chunk=32)
        if self.frontend:
            kw["frontend"] = dataclasses.replace(
                self.frontend, num_positions=16, feature_dim=kw["d_model"]
            )
        if self.stack_pattern:
            # shrink the pattern to 2 layers, keeping >=1 of each block type
            kinds = []
            for kind, _ in self.stack_pattern:
                if kind not in kinds:
                    kinds.append(kind)
            kw["stack_pattern"] = tuple((k, 1) for k in kinds[:2]) or ()
            kw["num_layers"] = sum(c for _, c in kw["stack_pattern"])
        return self.with_(name=self.name + "-smoke", **kw)
