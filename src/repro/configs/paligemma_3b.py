"""paligemma-3b — SigLIP + Gemma-2B VLM [arXiv:2407.07726].

The SigLIP vision tower + projector is the assignment's frontend STUB:
input_specs() delivers (B, 256, 2048) projected patch embeddings; the
18-layer Gemma decoder (MQA kv=1, head_dim 256, geglu d_ff=16384) is
implemented here.
"""

from repro.configs.base import FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    rope_theta=1e4,
    mlp_act="gelu",
    tie_embeddings=True,
    frontend=FrontendStub(kind="vision", num_positions=256, feature_dim=2048),
    source="arXiv:2407.07726",
)
