"""Synthetic data pipelines (the container has no datasets).

Deterministic, seedable, shardable generators for:
* token streams with Zipfian unigram structure + Markov bigram structure
  (so a language model has something learnable);
* continuous "latent" sequences for the diffusion-LM mode (mixture of
  anisotropic Gaussians in embedding space — the diffusion solvers have a
  multi-modal target with known statistics);
* stub frontend features (audio frames / vision patches).

The host-side loader yields numpy batches; `shard_batch` places them on the
device mesh with the run's input sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    kind: str = "lm"  # lm | diffusion
    d_model: int = 0  # diffusion mode
    num_modes: int = 8


class TokenStream:
    """Zipf unigrams modulated by a random sparse Markov chain."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # each token strongly predicts a handful of successors
        self.succ = rng.integers(0, v, size=(v, 4))

    def batches(self) -> Iterator[dict]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + 1)
        v = cfg.vocab_size
        while True:
            toks = np.empty((cfg.batch_size, cfg.seq_len), np.int32)
            cur = rng.choice(v, size=cfg.batch_size, p=self.unigram)
            toks[:, 0] = cur
            for t in range(1, cfg.seq_len):
                use_markov = rng.random(cfg.batch_size) < 0.7
                pick = self.succ[cur, rng.integers(0, 4, cfg.batch_size)]
                fresh = rng.choice(v, size=cfg.batch_size, p=self.unigram)
                cur = np.where(use_markov, pick, fresh).astype(np.int32)
                toks[:, t] = cur
            yield {"tokens": toks}


class GaussianMixtureLatents:
    """Mixture-of-Gaussians targets in R^(S x D) for diffusion training.

    Known first/second moments let benchmarks score generated samples
    without FID (moment errors + mode coverage).
    """

    def __init__(self, cfg: DataConfig):
        assert cfg.d_model > 0
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        k, d = cfg.num_modes, cfg.d_model
        self.means = rng.normal(0, 1.0, size=(k, d)).astype(np.float32)
        self.scales = (0.15 + 0.2 * rng.random((k, d))).astype(np.float32)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        cfg = self.cfg
        k = cfg.num_modes
        comp = rng.integers(0, k, size=(n, cfg.seq_len))
        eps = rng.normal(size=(n, cfg.seq_len, cfg.d_model)).astype(np.float32)
        return self.means[comp] + self.scales[comp] * eps

    def batches(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.cfg.seed + 1)
        while True:
            yield {"latents": self.sample(rng, self.cfg.batch_size)}

    # analytic moments, for benchmark scoring
    def moments(self) -> tuple[np.ndarray, np.ndarray]:
        mu = self.means.mean(0)
        second = (self.means**2 + self.scales**2).mean(0)
        return mu, second - mu**2


def frontend_features(
    rng: np.random.Generator, batch: int, positions: int, dim: int
) -> np.ndarray:
    """Stub modality features: smooth low-rank signals, not white noise."""
    basis = rng.normal(size=(16, dim)).astype(np.float32)
    coef = rng.normal(size=(batch, positions, 16)).astype(np.float32)
    t = np.linspace(0, 1, positions, dtype=np.float32)[None, :, None]
    return np.tanh(coef @ basis * 0.3 + np.sin(8 * np.pi * t))


def make_loader(cfg: DataConfig):
    if cfg.kind == "lm":
        return TokenStream(cfg)
    if cfg.kind == "diffusion":
        return GaussianMixtureLatents(cfg)
    raise ValueError(f"unknown data kind {cfg.kind!r}")
