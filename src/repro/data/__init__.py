from repro.data.synthetic import (
    DataConfig,
    GaussianMixtureLatents,
    TokenStream,
    frontend_features,
    make_loader,
)
