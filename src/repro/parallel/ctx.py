"""Activation-sharding constraints, injected contextually.

GSPMD propagates parameter shardings onto activations "sideways" — with
FSDP-sharded weights it can assign batch activations bizarre layouts
(observed: embedding-lookup results partitioned over the fsdp axis,
triggering involuntary full rematerialization).  Production frameworks pin
activation layouts explicitly (MaxText's ``with_logical_constraint``); here
launchers install the data-axis names once and the model calls
:func:`constrain` at stack boundaries.

No-op when no axes are installed (CPU smoke tests, single-device runs).
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

_DATA_AXES: contextvars.ContextVar[tuple[str, ...] | None] = (
    contextvars.ContextVar("repro_data_axes", default=None)
)
_MODEL_AXIS: contextvars.ContextVar[str | None] = (
    contextvars.ContextVar("repro_model_axis", default=None)
)
_SEQ_PARALLEL: contextvars.ContextVar[bool] = (
    contextvars.ContextVar("repro_seq_parallel", default=False)
)


@contextlib.contextmanager
def activation_sharding(
    data_axes: tuple[str, ...], model_axis: str | None = "model",
    seq_parallel: bool = False,
):
    """seq_parallel=True additionally shards (B, S, d) activations' sequence
    dim over the model axis at stack boundaries (Megatron-SP style): GSPMD
    then converts the per-block TP all-reduces into reduce-scatter +
    all-gather pairs around the sharded residual stream."""
    tok = _DATA_AXES.set(tuple(data_axes))
    tok2 = _MODEL_AXIS.set(model_axis)
    tok3 = _SEQ_PARALLEL.set(seq_parallel)
    try:
        yield
    finally:
        _DATA_AXES.reset(tok)
        _MODEL_AXIS.reset(tok2)
        _SEQ_PARALLEL.reset(tok3)


def constrain_batch(x: jax.Array, batch_dim: int = 0) -> jax.Array:
    """Pin dim `batch_dim` to the data axes, others replicated — or, in
    sequence-parallel mode, shard dim 1 of (B, S, d) over the model axis."""
    axes = _DATA_AXES.get()
    if axes is None:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = axes
    if (
        _SEQ_PARALLEL.get()
        and x.ndim == 3
        and batch_dim == 0
        and _MODEL_AXIS.get() is not None
        and x.shape[1] % 16 == 0
    ):
        spec[1] = _MODEL_AXIS.get()
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_dims(x: jax.Array, dims: tuple) -> jax.Array:
    """Pin dims by role: "dp" -> data axes, "tp" -> model axis, None ->
    replicated.  No-op outside an activation_sharding context."""
    axes = _DATA_AXES.get()
    if axes is None:
        return x
    model = _MODEL_AXIS.get()
    spec = []
    for d in dims:
        if d == "dp":
            spec.append(axes)
        elif d == "tp":
            spec.append(model)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
