"""Logical sharding rules: pytree path + shape -> PartitionSpec.

Axis convention (DESIGN.md §5):
  * batch-like dims        -> the data axes ("pod","data") / ("data",)
  * heads / d_ff / vocab   -> "model" (tensor parallel), guarded by
                              divisibility — non-divisible dims (e.g. 25
                              Hymba heads, 8 Mixtral KV heads on tp=16)
                              replicate, which is the production reality of
                              KV-replicated GQA tensor parallelism
  * experts                -> "model" when expert count divides (DeepSeek
                              64/16 -> expert parallel); else expert FFN dim
  * layer-stacked leading dim (inside "segs/") -> never sharded (scanned)

Everything is derived from path strings over the spec tree, so the same
rules shard real params, abstract params, optimizer mirrors, and caches.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def dp_size(mesh: Mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def round_to_dp(n: int, mesh: Mesh | None) -> int:
    """Smallest multiple of the mesh's data-parallel size that is >= n.

    The serving engine rounds batch buckets with this so every fused batch
    splits evenly across the data axes (no ragged shards)."""
    if mesh is None:
        return n
    dp = dp_size(mesh)
    return -(-n // dp) * dp


class SamplerSpecs(NamedTuple):
    """PartitionSpecs for a solver program's sampling scan carry.

    The field set covers the union of the registry programs' carries: the
    latents ``x`` (batch-leading, every solver), the eps history ``eps_buf``
    ``(cap, B, ...)`` — batch is axis 1, like KV caches — and replicated
    ``t_buf`` time grid (ERA / Adams-family history buffers), and the
    per-sample solver state ``delta_eps`` ((B,) for per-sample ERS, scalar
    otherwise).  ``lengths`` places the mixed-seq-len path's per-row (B,)
    valid-length vector batch-aligned with its rows, so the masked error
    norms stay shard-local.  ``active_steps`` / ``step_ts`` are the
    mixed-NFE path's :class:`~repro.core.program.StepMask` channel: the
    per-row (B,) step counts and (B, n_steps + 1) per-row time grids shard
    batch-aligned with their rows, so each shard reads only its own rows'
    grids and activity.  Programs read the fields their carry uses and
    ignore the rest (DDIM touches only ``x``; DPM++(2M)'s ``x0_prev``
    shards like ``x``).
    """

    x: P
    eps_buf: P
    t_buf: P
    delta_eps: P
    lengths: P
    active_steps: P
    step_ts: P


class SamplerShardings(NamedTuple):
    """``SamplerSpecs`` bound to a concrete mesh (NamedSharding leaves)."""

    x: NamedSharding
    eps_buf: NamedSharding
    t_buf: NamedSharding
    delta_eps: NamedSharding
    lengths: NamedSharding
    active_steps: NamedSharding
    step_ts: NamedSharding


def sampler_pspecs(
    mesh: Mesh,
    *,
    batch: int | None = None,
    per_sample: bool = True,
    x_ndim: int = 3,
) -> SamplerSpecs:
    """Scan-carry PartitionSpecs for the batched sampling engine.

    Everything shards only along the batch dimension over the mesh's data
    axes; per-sample ERS then keeps the whole solver loop collective-free
    (each shard measures its own rows' delta_eps and selects its own
    Lagrange bases).  If ``batch`` is given and does not divide the
    data-parallel size, every entry degrades to replicated — correct, just
    not parallel — so exact-size (unpadded) runs never hit a ragged-shard
    jit error.
    """
    dp: Any = data_axes(mesh)
    if not dp or (batch is not None and not _div(batch, dp_size(mesh))):
        dp = None
    rest = (None,) * (x_ndim - 1)
    return SamplerSpecs(
        x=P(dp, *rest),
        eps_buf=P(None, dp, *rest),
        t_buf=P(),
        delta_eps=P(dp) if per_sample else P(),
        lengths=P(dp),
        active_steps=P(dp),
        step_ts=P(dp, None),
    )


def sampler_shardings(
    mesh: Mesh,
    *,
    batch: int | None = None,
    per_sample: bool = True,
    x_ndim: int = 3,
) -> SamplerShardings:
    """``sampler_pspecs`` materialized as NamedShardings on ``mesh`` (what
    a program's ``sample_scan`` takes as its ``shardings`` argument)."""
    specs = sampler_pspecs(
        mesh, batch=batch, per_sample=per_sample, x_ndim=x_ndim
    )
    return SamplerShardings(*(NamedSharding(mesh, s) for s in specs))


def solver_carry_pspecs(
    mesh: Mesh,
    program,
    config,
    *,
    batch: int | None = None,
    x_ndim: int = 3,
) -> SamplerSpecs:
    """Carry PartitionSpecs for a :class:`repro.core.SolverProgram`.

    The program declares whether its carry holds per-sample ``(B,)`` solver
    state (``per_sample_state(cfg)``); everything else follows the shared
    batch-over-data-axes layout of :func:`sampler_pspecs`."""
    return sampler_pspecs(
        mesh,
        batch=batch,
        per_sample=program.per_sample_state(config),
        x_ndim=x_ndim,
    )


def solver_carry_shardings(
    mesh: Mesh,
    program,
    config,
    *,
    batch: int | None = None,
    x_ndim: int = 3,
) -> SamplerShardings:
    """:func:`solver_carry_pspecs` bound to ``mesh`` as NamedShardings."""
    return sampler_shardings(
        mesh,
        batch=batch,
        per_sample=program.per_sample_state(config),
        x_ndim=x_ndim,
    )


class ParamReplicator:
    """Replicate a params tree over a mesh, caching the placed copy.

    The cache key is the identity of every leaf, not of the container —
    callers that rebuild or mutate their params dict between calls (a
    finetune-and-sample loop) get a fresh placement instead of silently
    sampling with the first call's weights."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self._sharding = NamedSharding(mesh, P())
        # the cached leaves are held alongside their ids: id() values are
        # only unique among live objects, so pinning the leaves is what
        # makes the identity key trustworthy across caller-side rebuilds
        self._cached_leaves: list | None = None
        self._placed: Any = None

    @property
    def sharding(self) -> NamedSharding:
        """The fully-replicated placement every leaf is committed to —
        what an AOT caller attaches to its params avals so the compiled
        program accepts replicated leaves without resharding."""
        return self._sharding

    def __call__(self, params):
        leaves = jax.tree.leaves(params)
        stale = (
            self._cached_leaves is None
            or len(leaves) != len(self._cached_leaves)
            or any(a is not b for a, b in zip(leaves, self._cached_leaves))
        )
        if stale:
            self._placed = jax.tree.map(
                lambda a: jax.device_put(a, self._sharding), params
            )
            self._cached_leaves = leaves
        return self._placed


class ShardingRules:
    """fsdp=True additionally shards each large parameter's biggest
    unsharded dim over the "data" axis (ZeRO-3 / MaxText fsdp style) —
    required for the 67B-class train_4k combos to fit 16 GB HBM."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, fsdp: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.tp = tp_size(mesh)
        self.dp = data_axes(mesh)
        self.fsdp = fsdp
        self.fsdp_axis = "data" if "data" in mesh.axis_names else None
        self.fsdp_size = mesh.shape.get("data", 1)

    # -- parameter rules ---------------------------------------------------
    def _param_spec(self, path: str, shape: tuple[int, ...]) -> P:
        tp, cfg = self.tp, self.cfg
        mdl = "model"

        def out_col(ncols):  # shard a (in, out) matmul's out dim
            return P(None, mdl) if _div(ncols, tp) else P(None, None)

        def in_row(nrows):   # shard a (in, out) matmul's in dim
            return P(mdl, None) if _div(nrows, tp) else P(None, None)

        leaf = path.rsplit("/", 1)[-1]
        if path.endswith("embed") or path == "embed":
            return P(mdl, None) if _div(shape[0], tp) else P(None, None)
        if "pos_embed" in path:
            return P(mdl, None) if _div(shape[0], tp) else P(None, None)
        if "lm_head" in path:
            return out_col(shape[-1])
        if "meta" in path:
            return P(None, None)

        # xLSTM blocks: per-head recurrent math with nh << tp; replicate
        # (the arch is small — data parallel carries it; see DESIGN.md)
        if "mlstm" in path or "slstm" in path:
            return P(*([None] * len(shape)))

        if "experts" in path and len(shape) == 3:
            e, a, b = shape
            if _div(e, tp):
                return P(mdl, None, None)        # expert parallel
            # tensor-parallel experts: shard the ff dim
            if path.endswith("wo"):              # (E, ff, d)
                return P(None, mdl, None) if _div(a, tp) else P(None, None, None)
            return P(None, None, mdl) if _div(b, tp) else P(None, None, None)
        if "router" in path:
            return P(None, None)

        if any(s in path for s in ("/attn/", "self_attn", "cross_attn", "/mla/")):
            if leaf == "b":
                return P(mdl) if _div(shape[0], tp) else P(None)
            if any(path.endswith(s) for s in ("wq/w", "wk/w", "wv/w", "wkv_b/w")):
                return out_col(shape[-1])
            if path.endswith("wo/w"):
                return in_row(shape[0])
            return P(*([None] * len(shape)))     # wkv_a, norms

        if "mamba" in path:
            if path.endswith("in_proj/w"):
                return out_col(shape[-1])
            if path.endswith("out_proj/w"):
                return in_row(shape[0])
            if leaf == "A_log" or leaf == "D":
                return (
                    P(mdl, None) if len(shape) == 2 and _div(shape[0], tp)
                    else (P(mdl) if _div(shape[0], tp) else P(*([None] * len(shape))))
                )
            if path.endswith("x_proj/w") or path.endswith("dt_proj/w"):
                return in_row(shape[0])
            if path.endswith("dt_proj/b"):
                return P(mdl) if _div(shape[0], tp) else P(None)
            if "conv" in path:
                return (
                    P(None, mdl) if len(shape) == 2 and _div(shape[-1], tp)
                    else (P(mdl) if _div(shape[0], tp) else P(None))
                )
            return P(*([None] * len(shape)))

        if "mlp" in path or "shared" in path:
            if leaf == "b":
                return P(mdl) if _div(shape[0], tp) else P(None)
            if path.endswith("wo/w"):
                return in_row(shape[0])
            return out_col(shape[-1])

        return P(*([None] * len(shape)))

    def _apply_fsdp(self, spec: P, shape: tuple[int, ...]) -> P:
        import math
        if (
            not self.fsdp
            or self.fsdp_axis is None
            or math.prod(shape) < (1 << 20)
        ):
            return spec
        parts = list(spec) + [None] * (len(shape) - len(spec))
        # biggest unsharded dim divisible by the data axis
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if parts[i] is None and _div(shape[i], self.fsdp_size):
                parts[i] = self.fsdp_axis
                break
        return P(*parts)

    def param_pspec(self, tree) -> Any:
        """PartitionSpecs for a (spec/abstract/real) param tree."""

        def visit(path, leaf):
            pstr = "/".join(_key_str(k) for k in path)
            shape = tuple(leaf.shape)
            # embedding tables are gathered by token id — FSDP-sharding their
            # feature dim forces SPMD into full rematerialization
            fsdp_ok = "embed" not in pstr
            if "segs/" in pstr or pstr.startswith("segs"):
                inner = self._param_spec(pstr, shape[1:])
                if fsdp_ok:
                    inner = self._apply_fsdp(inner, shape[1:])
                return P(None, *inner)
            spec = self._param_spec(pstr, shape)
            return self._apply_fsdp(spec, shape) if fsdp_ok else spec

        return jax.tree_util.tree_map_with_path(visit, tree)

    def param_sharding(self, tree) -> Any:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.param_pspec(tree)
        )

    # -- optimizer state mirrors the params ---------------------------------
    def opt_sharding(self, opt_tree) -> Any:
        pspec = {
            "m": self.param_pspec(opt_tree["m"]),
            "v": self.param_pspec(opt_tree["v"]),
            "step": P(),
        }
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), pspec)

    # -- batch / cache -------------------------------------------------------
    def _dp_if_divisible(self, n: int):
        total = 1
        for a in self.dp:
            total *= self.mesh.shape[a]
        return self.dp if _div(n, total) else None

    def batch_sharding(self, batch_tree) -> Any:
        def visit(path, leaf):
            pstr = "/".join(_key_str(k) for k in path)
            shape = tuple(leaf.shape)
            if len(shape) == 0:
                return NamedSharding(self.mesh, P())
            dp = self._dp_if_divisible(shape[0])
            rest = [None] * (len(shape) - 1)
            return NamedSharding(self.mesh, P(dp, *rest))

        return jax.tree_util.tree_map_with_path(visit, batch_tree)

    def cache_sharding(self, cache_tree) -> Any:
        """Caches: (L, B, slots, ...) -> batch over data axes; large slot
        dims over "model" (kv heads < tp for every assigned arch, so
        sequence-sharding the cache is what bounds decode memory)."""

        def visit(path, leaf):
            pstr = "/".join(_key_str(k) for k in path)
            shape = tuple(leaf.shape)
            if len(shape) <= 2:  # (L, slots) position arrays etc.
                return NamedSharding(self.mesh, P(*([None] * len(shape))))
            dp = self._dp_if_divisible(shape[1])
            rest = [None] * (len(shape) - 2)
            # k/v/ckv caches: (L, B, slots, ...) — shard big slot dims
            if len(shape) >= 4 and shape[2] >= 4096 and _div(shape[2], self.tp):
                rest[0] = "model"
            return NamedSharding(self.mesh, P(None, dp, *rest))

        return jax.tree_util.tree_map_with_path(visit, cache_tree)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)
