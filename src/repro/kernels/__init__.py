"""Pallas TPU kernels (+ jnp oracles in ref.py, jit wrappers in ops.py)."""
