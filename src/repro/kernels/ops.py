"""jit'd public wrappers around the Pallas kernels.

Handles the hardware-alignment plumbing so callers keep natural shapes:
* pads head_dim to a 128 multiple and seq lens to block multiples
  (padded key slots get position -1 => masked out; padded head dims are
  zeros => contribute nothing to dot products, scale uses the true hd);
* pads GQA group G to the f32 sublane multiple (8) for the decode kernel;
* auto-selects interpret mode off-TPU so the same call sites work in CPU
  tests and on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import era_update as _era
from repro.kernels import flash_attention as _fa
from repro.core.lagrange import lagrange_weights

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: Array, mult: int, axis: int, value=0) -> Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("window", "causal", "softcap", "protected", "block_q", "block_k"),
)
def flash_attention(
    q: Array,       # (B, Sq, H, hd) — model layout
    k: Array,       # (B, Sk, KV, hd)
    v: Array,
    q_pos: Array,
    kv_pos: Array,
    *,
    kv_mask: Array | None = None,  # (B, Sk) bool/int, nonzero = valid key
    window: int = 0,
    causal: bool = True,
    softcap: float = 0.0,
    protected: int = 0,
    block_q: int = 128,
    block_k: int = 128,
) -> Array:
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    bq = min(block_q, max(8, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(128, 0) if sk >= 128 else 128)
    # kernel layout (B, H, S, hd)
    qt = _pad_to(_pad_to(q.transpose(0, 2, 1, 3), 128, 3), bq, 2)
    kt = _pad_to(_pad_to(k.transpose(0, 2, 1, 3), 128, 3), bk, 2)
    vt = _pad_to(_pad_to(v.transpose(0, 2, 1, 3), 128, 3), bk, 2)
    qp = _pad_to(q_pos.astype(jnp.int32), bq, 0, value=-(10**9))
    kp = _pad_to(kv_pos.astype(jnp.int32), bk, 0, value=-1)
    km = (
        None
        if kv_mask is None
        else _pad_to(kv_mask.astype(jnp.int32), bk, 1, value=0)
    )
    out = _fa.flash_attention(
        qt, kt, vt, qp, kp,
        window=window, causal=causal, softcap=softcap, protected=protected,
        scale=hd**-0.5, block_q=bq, block_k=bk,
        interpret=_interpret(), kv_mask=km,
    )
    return out[:, :, :sq, :hd].transpose(0, 2, 1, 3)


@functools.partial(
    jax.jit, static_argnames=("window", "protected", "block_k")
)
def decode_attention(
    q: Array,       # (B, 1, H, hd) or (B, H, hd)
    k: Array,       # (B, S, KV, hd) cache layout
    v: Array,
    q_pos: Array,   # scalar
    kv_pos: Array,  # (S,)
    *,
    window: int = 0,
    protected: int = 0,
    block_k: int = 128,
) -> Array:
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    b, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    gp = -(-g // 8) * 8  # pad group rows to sublane multiple
    qt = _pad_to(q.reshape(b, kvh, g, hd), 128, 3)
    if gp != g:
        qt = _pad_to(qt, gp, 2)
    qt = qt.reshape(b, kvh * gp, qt.shape[-1])
    kt = _pad_to(_pad_to(k.transpose(0, 2, 1, 3), 128, 3), block_k, 2)
    vt = _pad_to(_pad_to(v.transpose(0, 2, 1, 3), 128, 3), block_k, 2)
    kp = _pad_to(kv_pos.astype(jnp.int32), block_k, 0, value=-1)
    out = _dec.decode_attention(
        qt, kt, vt, q_pos, kp,
        window=window, protected=protected, scale=hd**-0.5,
        block_k=block_k, interpret=_interpret(),
    )
    out = out.reshape(b, kvh, gp, -1)[:, :, :g, :hd].reshape(b, h, hd)
    return out[:, None] if squeeze else out


@functools.partial(jax.jit, static_argnames=("block",))
def era_step(
    x: Array,          # sample, any shape
    eps_sel: Array,    # (k, *x.shape)
    t_sel: Array,      # (k,)
    e_hist: Array,     # (3, *x.shape)
    t_next: Array,
    cx: Array,
    ce: Array,
    am4: Array,        # (4,)
    *,
    block: int = 4096,
) -> tuple[Array, Array]:
    """Fused ERA step on arbitrary-shaped samples. Returns (x_next, eps_bar)."""
    shape = x.shape
    n = x.size
    # shrink the block for small samples (e.g. per-sample vmap tiles) so the
    # pad-to-block waste stays bounded; 128 keeps TPU lanes full
    block = max(128, min(block, 1 << max(n - 1, 1).bit_length()))
    lag_w = lagrange_weights(t_sel, t_next)
    xf = _pad_to(x.reshape(-1), block, 0)
    es = _pad_to(eps_sel.reshape(eps_sel.shape[0], -1), block, 1)
    eh = _pad_to(e_hist.reshape(3, -1), block, 1)
    x_next, eps_bar = _era.era_update(
        xf, es, lag_w, eh, am4, cx, ce, block=block, interpret=_interpret()
    )
    return x_next[:n].reshape(shape), eps_bar[:n].reshape(shape)


def era_combine(eps_sel, t_sel, e_hist, t_next, am4=None):
    """Drop-in for repro.core.era.era_combine backed by the fused kernel
    (combine only — the DDIM x-update stays outside; used when the solver
    requested use_fused_update but the caller manages x itself)."""
    from repro.core.era import AM4

    am4 = jnp.asarray(AM4 if am4 is None else am4, jnp.float32)
    x_dummy = jnp.zeros(eps_sel.shape[1:], eps_sel.dtype)
    x_next, eps_bar = era_step(
        x_dummy, eps_sel, t_sel, e_hist, t_next,
        jnp.float32(0.0), jnp.float32(1.0), am4,
    )
    # with cx=0, ce=1 the kernel's x_next equals eps_corr
    return eps_bar, x_next


def fused_step_parity(
    shape: tuple[int, ...] = (4, 96),
    k: int = 4,
    seed: int = 0,
) -> float:
    """Max abs error of the fused `era_step` vs the reference combine + DDIM
    update on a random probe — the numerics gate for the fused default path
    (runs in interpret mode off-TPU).  Returns the error; callers decide the
    tolerance (1e-5 is comfortable in f32).

    Must run eagerly: it executes the kernel and converts the error to a
    Python float, neither of which works under an ambient jit trace (the
    gate in ``core.era._fused_ops`` guards that case)."""
    from repro.core.era import AM4, era_combine

    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(keys[0], shape, jnp.float32)
    eps_sel = jax.random.normal(keys[1], (k,) + shape, jnp.float32)
    e_hist = jax.random.normal(keys[2], (3,) + shape, jnp.float32)
    t_sel = jnp.linspace(0.9, 0.3, k)
    t_next = jnp.float32(0.25)
    cx, ce = jnp.float32(0.97), jnp.float32(-0.05)
    am4 = jnp.asarray(AM4, jnp.float32)
    x_next, eps_bar = era_step(x, eps_sel, t_sel, e_hist, t_next, cx, ce, am4)
    eb_ref, ec_ref = era_combine(eps_sel, t_sel, e_hist, t_next)
    x_ref = cx * x + ce * ec_ref
    err = jnp.maximum(
        jnp.max(jnp.abs(x_next - x_ref)), jnp.max(jnp.abs(eps_bar - eb_ref))
    )
    return float(err)
