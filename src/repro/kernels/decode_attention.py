"""Single-token GQA decode attention over a (ring-buffer) KV cache.

One query token per sequence; rows of the MXU tile are the G query heads
sharing a kv head (padded to the sublane multiple by ops.py).  Grid is
``(batch*kv_heads, kv_blocks)`` with online-softmax state in VMEM scratch —
the decode-time analogue of the flash kernel, reading the cache exactly
once per step.  Ring-buffer semantics come for free from the positional
mask (slot position -1 = empty, window/protected predicates fused).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    qpos_ref, kpos_ref, q_ref, k_ref, v_ref,
    o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    window: int,
    protected: int,
    nk: int,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)        # (G, hd)
    k = k_ref[0].astype(jnp.float32)        # (bk, hd)
    v = v_ref[0].astype(jnp.float32)        # (bk, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                               # (G, bk)

    qp = qpos_ref[0]                        # scalar
    kp = kpos_ref[...][None, :]             # (1, bk)
    valid = (kp >= 0) & (kp <= qp)
    if window > 0:
        in_w = kp > qp - window
        if protected > 0:
            in_w |= kp < protected
        valid &= in_w
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, :, :] = (
            acc_ref[...] / jnp.where(l > 0.0, l, 1.0)[:, None]
        ).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,       # (B, H, hd) — one token; H = KV * G
    k: jax.Array,       # (B, KV, S, hd)
    v: jax.Array,       # (B, KV, S, hd)
    q_pos: jax.Array,   # scalar int32 absolute position
    kv_pos: jax.Array,  # (S,) int32 slot positions (-1 empty)
    *,
    window: int = 0,
    protected: int = 0,
    scale: float | None = None,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, hd = q.shape
    kvh, s = k.shape[1], k.shape[2]
    g = h // kvh
    assert s % block_k == 0, (s, block_k)
    nk = s // block_k
    grid = (b * kvh, nk)

    kernel = functools.partial(
        _decode_kernel,
        scale=hd**-0.5 if scale is None else scale,
        window=window,
        protected=protected,
        nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bk_, ik: (0,)),
            pl.BlockSpec((block_k,), lambda bk_, ik: (ik,)),
            pl.BlockSpec((1, g, hd), lambda bk_, ik: (bk_, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bk_, ik: (bk_, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bk_, ik: (bk_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda bk_, ik: (bk_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(
        jnp.atleast_1d(q_pos).astype(jnp.int32),
        kv_pos.astype(jnp.int32),
        q.reshape(b * kvh, g, hd),
        k.reshape(b * kvh, s, hd),
        v.reshape(b * kvh, s, hd),
    )
    return out.reshape(b, h, hd)
