"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are the semantic ground truth; the kernels must match them on every
shape/dtype the tests sweep.  They are also the fallbacks the framework uses
on non-TPU backends outside interpret-mode tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def _bias(q_pos, kv_pos, window: int, causal: bool, protected: int = 0):
    q = q_pos[:, None]
    k = kv_pos[None, :]
    valid = k >= 0
    if causal:
        valid &= k <= q
    if window > 0:
        in_w = k > q - window
        if protected > 0:
            in_w |= k < protected
        valid &= in_w
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def flash_attention_ref(
    q: Array,        # (B, H, Sq, hd)
    k: Array,        # (B, KV, Sk, hd)
    v: Array,        # (B, KV, Sk, hd)
    q_pos: Array,    # (Sq,) int32
    kv_pos: Array,   # (Sk,) int32
    *,
    window: int = 0,
    causal: bool = True,
    softcap: float = 0.0,
    protected: int = 0,
    kv_mask: Array | None = None,  # (B, Sk), nonzero = valid key
) -> Array:
    b, h, sq, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, sq, hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k).astype(jnp.float32) * (hd**-0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = s + _bias(q_pos, kv_pos, window, causal, protected)
    if kv_mask is not None:  # per-row pad-key mask (mixed-seq-len batches)
        s = s + jnp.where(kv_mask != 0, 0.0, NEG_INF).astype(jnp.float32)[
            :, None, None, None, :
        ]
    w = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (all -inf) -> zeros, matching the kernel
    any_valid = jnp.max(s, axis=-1, keepdims=True) > NEG_INF / 2
    w = jnp.where(any_valid, w, 0.0)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w.astype(v.dtype), v)
    return out.reshape(b, h, sq, hd)


def decode_attention_ref(
    q: Array,        # (B, H, hd) single query token
    k: Array,        # (B, KV, S, hd) cache
    v: Array,        # (B, KV, S, hd)
    q_pos: Array,    # scalar int32 (absolute position)
    kv_pos: Array,   # (S,) int32, -1 = empty slot
    *,
    window: int = 0,
    protected: int = 0,
) -> Array:
    b, h, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k).astype(jnp.float32) * (hd**-0.5)
    bias = _bias(q_pos[None], kv_pos, window, True, protected)[0]  # (S,)
    s = s + bias
    w = jax.nn.softmax(s, axis=-1)
    any_valid = jnp.max(s, axis=-1, keepdims=True) > NEG_INF / 2
    w = jnp.where(any_valid, w, 0.0)
    out = jnp.einsum("bkgs,bksd->bkgd", w.astype(v.dtype), v)
    return out.reshape(b, h, hd)


def era_update_ref(
    x: Array,          # (N,) current sample x_ti (flattened)
    eps_sel: Array,    # (k, N) ERS-selected buffer noises
    lag_w: Array,      # (k,) Lagrange weights at t_{i+1}
    e_hist: Array,     # (3, N) eps at steps i, i-1, i-2
    am4: Array,        # (4,) Adams-Moulton coefficients
    cx: Array,         # scalar DDIM x coefficient
    ce: Array,         # scalar DDIM eps coefficient
) -> tuple[Array, Array]:
    """Fused ERA step: predictor combine + AM4 corrector + DDIM update.

    Returns (x_next, eps_bar).  Everything in f32.
    """
    eps_bar = jnp.tensordot(lag_w.astype(jnp.float32), eps_sel.astype(jnp.float32), axes=(0, 0))
    eps_corr = (
        am4[0] * eps_bar
        + am4[1] * e_hist[0].astype(jnp.float32)
        + am4[2] * e_hist[1].astype(jnp.float32)
        + am4[3] * e_hist[2].astype(jnp.float32)
    )
    x_next = cx * x.astype(jnp.float32) + ce * eps_corr
    return x_next.astype(x.dtype), eps_bar.astype(x.dtype)
