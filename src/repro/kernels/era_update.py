"""Fused ERA-Solver update step (the paper's per-step non-network math).

Per sampling step, ERA-Solver touches image/latent-sized tensors several
times: k Lagrange-buffer reads for the predictor combine (Eq. 13/14), three
history reads for the Adams--Moulton corrector (Eq. 11), and the DDIM
x-update (Eq. 8).  Composed naively that is ~(k+5) HBM round trips over the
sample; fused here it is a single pass — each operand is read once from HBM
into a VMEM tile, and x_{i+1} / eps_bar are written once.

Grid: 1-D over flattened-sample blocks.  Scalar operands (Lagrange weights,
AM4 coefficients, DDIM cx/ce) ride in SMEM via PrefetchScalarGridSpec so
they are resident before the tile loop starts.

This kernel is the *default* ERA step path (``ERAConfig.use_fused_update``):
``repro.kernels.ops.era_step`` auto-selects ``interpret=True`` off-TPU, and
``repro.kernels.ops.fused_step_parity`` gates its numerics against the
pure-jnp reference combine.  Per-sample ERS batches vmap this kernel (the
pallas batching rule prepends a grid dimension).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _era_kernel(
    lag_w_ref,   # SMEM (k,)
    am4_ref,     # SMEM (4,)
    cxce_ref,    # SMEM (2,)
    x_ref,       # (bs,)
    eps_sel_ref, # (k, bs)
    e_hist_ref,  # (3, bs)
    x_out_ref,   # (bs,)
    eps_bar_ref, # (bs,)
    *,
    k: int,
):
    x = x_ref[...].astype(jnp.float32)
    eps_bar = jnp.zeros_like(x)
    for m in range(k):  # k static, fully unrolled vector FMA chain
        eps_bar += lag_w_ref[m] * eps_sel_ref[m, :].astype(jnp.float32)
    eps_corr = (
        am4_ref[0] * eps_bar
        + am4_ref[1] * e_hist_ref[0, :].astype(jnp.float32)
        + am4_ref[2] * e_hist_ref[1, :].astype(jnp.float32)
        + am4_ref[3] * e_hist_ref[2, :].astype(jnp.float32)
    )
    x_out_ref[...] = (cxce_ref[0] * x + cxce_ref[1] * eps_corr).astype(
        x_out_ref.dtype
    )
    eps_bar_ref[...] = eps_bar.astype(eps_bar_ref.dtype)


def era_update(
    x: jax.Array,        # (N,) flattened sample
    eps_sel: jax.Array,  # (k, N)
    lag_w: jax.Array,    # (k,)
    e_hist: jax.Array,   # (3, N)
    am4: jax.Array,      # (4,)
    cx: jax.Array,       # scalar
    ce: jax.Array,       # scalar
    *,
    block: int = 4096,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (x_next, eps_bar). N must be a multiple of `block` (ops.py
    pads)."""
    n = x.shape[0]
    kk = eps_sel.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)

    kernel = functools.partial(_era_kernel, k=kk)
    scalars = (
        lag_w.astype(jnp.float32),
        am4.astype(jnp.float32),
        jnp.stack([cx, ce]).astype(jnp.float32),
    )
    x_next, eps_bar = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block,), lambda i, *_: (i,)),
                pl.BlockSpec((kk, block), lambda i, *_: (0, i)),
                pl.BlockSpec((3, block), lambda i, *_: (0, i)),
            ],
            out_specs=[
                pl.BlockSpec((block,), lambda i, *_: (i,)),
                pl.BlockSpec((block,), lambda i, *_: (i,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=interpret,
    )(*scalars, x, eps_sel, e_hist)
    return x_next, eps_bar
