"""Flash attention, Pallas TPU kernel (prefill / training path).

Canonical TPU online-softmax pattern: 3-D grid ``(batch*heads, q_blocks,
kv_blocks)`` iterated sequentially on-core; the (acc, m, l) state lives in
VMEM scratch and persists across the innermost kv dimension.  Blocks are
MXU-aligned (q/kv block 128, head_dim padded to a multiple of 128 by the
ops.py wrapper).  GQA is expressed in the k/v BlockSpec index maps (q head
h reads kv head h // G), so no KV replication is materialized in HBM.

Masking is positional, matching :func:`repro.kernels.ref.flash_attention_ref`:
q_pos / kv_pos arrays carry absolute positions (-1 = invalid slot), and
window/causal/protected (attention-sink) predicates are fused into the
score block.  An optional per-row ``kv_mask`` operand ((B, Sk) int32,
nonzero = valid key) rides its own BlockSpec into the same score
predicate, so right-padded mixed-seq-len batches run this kernel instead
of falling back to chunked SDPA: masked-out keys contribute exp(-inf)=0
to the online softmax, and a kv block whose keys are all masked leaves
(acc, m, l) bitwise unchanged — a padded batch's valid positions compute
exactly the unpadded batch's math.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    # inputs (per BlockSpec): qpos, kpos, [kvmask], q, k, v
    qpos_ref, kpos_ref, *refs,
    scale: float,
    window: int,
    causal: bool,
    softcap: float,
    protected: int,
    nk: int,
    has_kv_mask: bool,
):
    if has_kv_mask:
        kvmask_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    else:
        kvmask_ref = None
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
    v = v_ref[0].astype(jnp.float32)                    # (bk, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                           # (bq, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    qp = qpos_ref[...][:, None]                         # (bq, 1)
    kp = kpos_ref[...][None, :]                         # (1, bk)
    valid = kp >= 0
    if kvmask_ref is not None:                          # per-row pad-key mask
        valid &= kvmask_ref[0][None, :] != 0
    if causal:
        valid &= kp <= qp
    if window > 0:
        in_w = kp > qp - window
        if protected > 0:
            in_w |= kp < protected
        valid &= in_w
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, :, :] = (
            acc_ref[...] / jnp.where(l > 0.0, l, 1.0)[:, None]
        ).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,       # (B, H, Sq, hd)
    k: jax.Array,       # (B, KV, Sk, hd)
    v: jax.Array,       # (B, KV, Sk, hd)
    q_pos: jax.Array,   # (Sq,) int32
    kv_pos: jax.Array,  # (Sk,) int32
    *,
    window: int = 0,
    causal: bool = True,
    softcap: float = 0.0,
    protected: int = 0,
    scale: float | None = None,   # defaults to hd**-0.5 (pre-padding value)
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    kv_mask: jax.Array | None = None,  # (B, Sk) int32, nonzero = valid key
) -> jax.Array:
    """Raw Pallas call: shapes must already be block-aligned (see ops.py)."""
    b, h, sq, hd = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    if kv_mask is not None:
        assert kv_mask.shape == (b, sk), (kv_mask.shape, b, sk)
    nq, nk = sq // block_q, sk // block_k
    grid = (b * h, nq, nk)

    def kv_index(bh, iq, ik):
        return ((bh // h) * kvh + (bh % h) // g, ik, 0)

    kernel = functools.partial(
        _flash_kernel,
        scale=hd**-0.5 if scale is None else scale,
        window=window,
        causal=causal,
        softcap=softcap,
        protected=protected,
        nk=nk,
        has_kv_mask=kv_mask is not None,
    )
    in_specs = [
        pl.BlockSpec((block_q,), lambda bh, iq, ik: (iq,)),
        pl.BlockSpec((block_k,), lambda bh, iq, ik: (ik,)),
    ]
    inputs = [q_pos.astype(jnp.int32), kv_pos.astype(jnp.int32)]
    if kv_mask is not None:
        # one (1, block_k) row slab per grid step, batch row bh // h
        in_specs.append(
            pl.BlockSpec((1, block_k), lambda bh, iq, ik: (bh // h, ik))
        )
        inputs.append(kv_mask.astype(jnp.int32))
    in_specs += [
        pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
        pl.BlockSpec((1, block_k, hd), kv_index),
        pl.BlockSpec((1, block_k, hd), kv_index),
    ]
    inputs += [
        q.reshape(b * h, sq, hd),
        k.reshape(b * kvh, sk, hd),
        v.reshape(b * kvh, sk, hd),
    ]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)
    return out.reshape(b, h, sq, hd)
