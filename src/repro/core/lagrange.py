"""Lagrange-interpolation predictor and error-robust selection (paper Sec. 3.2/3.3).

The predictor interpolates k previously observed network noises
{(t_tau_m, eps_theta(x_tau_m))} and evaluates the interpolant at t_{i+1}
(Eq. 13/14).  The *error-robust selection* (ERS, Eq. 16/17) chooses WHICH k
buffer entries become interpolation bases: k indices initialized uniformly
over the buffer are pushed toward the (more accurate) early part of the
buffer by a power function parameterized by the measured prediction error
delta_eps.

TPU adaptation: indices are computed as on-device scalars (no host sync) and
deduplicated with a static-k monotone pass so Lagrange nodes are strictly
increasing (duplicate nodes would divide by zero in the weights).  The paper
appends to a Python list and floors on the host; semantics are identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def lagrange_weights(t_nodes: Array, t_eval: Array) -> Array:
    """Weights l_m(t_eval) for nodes t_nodes (k,).  k is static.

    l_m(t) = prod_{l != m} (t - t_l) / (t_m - t_l)      (paper Eq. 13)
    """
    k = t_nodes.shape[0]
    t_nodes = t_nodes.astype(jnp.float32)
    t_eval = jnp.asarray(t_eval, jnp.float32)
    diff = t_nodes[:, None] - t_nodes[None, :]          # (k, k), m - l
    num = t_eval - t_nodes                              # (k,), t - t_l
    eye = jnp.eye(k, dtype=bool)
    # ratio[m, l] = (t - t_l) / (t_m - t_l), diagonal := 1
    ratio = jnp.where(eye, 1.0, num[None, :] / jnp.where(eye, 1.0, diff))
    return jnp.prod(ratio, axis=1)


def interpolate(eps_nodes: Array, t_nodes: Array, t_eval: Array) -> Array:
    """L_eps(t_eval) = sum_m l_m(t_eval) * eps_m   (paper Eq. 13/14)."""
    w = lagrange_weights(t_nodes, t_eval).astype(eps_nodes.dtype)
    return jnp.tensordot(w, eps_nodes, axes=(0, 0))


def _dedup_increasing(tau: list[Array], i: Array, k: int) -> Array:
    """Force tau strictly increasing within [0, i].  k is static."""
    out = []
    prev = jnp.int32(-1)
    for m in range(k):
        cur = jnp.maximum(tau[m], prev + 1)
        out.append(cur)
        prev = cur
    # backward clamp so the last index can still be <= i
    fixed = []
    nxt = i + 1
    for m in reversed(range(k)):
        cur = jnp.minimum(out[m], nxt - 1)
        fixed.append(cur)
        nxt = cur
    fixed.reverse()
    return jnp.stack([jnp.maximum(c, 0) for c in fixed])


def ers_select(i: Array, k: int, power: Array) -> Array:
    """Error-robust selection (Eq. 16/17).

    i      : current step index (buffer holds entries 0..i), traced scalar
    k      : interpolation order (static)
    power  : the exponent delta_eps / lambda (or a constant, for the
             Fig. 5/6 ablation)

    tau_hat_m = (i/k) * m,  m = 1..k        (Eq. 16)
    tau_m     = floor((tau_hat_m / i)^power * i) = floor((m/k)^power * i)
    """
    i_f = i.astype(jnp.float32)
    power = jnp.asarray(power, jnp.float32)
    taus = []
    for m in range(1, k + 1):
        frac = jnp.float32(m / k)
        taus.append(jnp.floor(frac**power * i_f).astype(jnp.int32))
    return _dedup_increasing(taus, i, k)


def fixed_select(i: Array, k: int) -> Array:
    """Fixed strategy: the last k entries (tau_m = i - (k-1) + m)."""
    return jnp.stack([i - (k - 1) + m for m in range(k)])


def select_bases(
    i: Array, k: int, delta_eps: Array, lam: float, strategy: str,
    const_power: float | None = None,
) -> Array:
    """Dispatch on selection strategy (static string)."""
    if strategy == "fixed":
        return fixed_select(i, k)
    if strategy == "ers":
        return ers_select(i, k, delta_eps / lam)
    if strategy == "const":
        # ablation: replace delta_eps/lambda with a constant power
        assert const_power is not None
        return ers_select(i, k, jnp.float32(const_power))
    raise ValueError(f"unknown selection strategy {strategy!r}")
