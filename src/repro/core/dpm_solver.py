"""DPM-Solver baselines (Lu et al. 2022a) — singlestep orders 1-3 + "fast".

Exponential-integrator solvers in half-logSNR (lambda) space; the linear
term of the diffusion ODE is integrated exactly, the eps nonlinearity is
approximated by Taylor expansion.  DPM-Solver-2 costs 2 NFE/step,
DPM-Solver-3 costs 3 NFE/step; DPM-Solver-fast packs a mix of orders to hit
an arbitrary NFE budget exactly (paper's comparison rows).

The step sequencing (orders per step) is static Python, so a sampling run is
an unrolled XLA program — fine for the solver benchmarks, and jit-cacheable
per (budget, schedule) pair.  DPM-Solver++(2M) (:func:`sample_pp2m`), the
multistep 1-NFE/step variant the serving engine cares about, is instead a
single ``jax.lax.scan`` program over the step grid
(:class:`DPMpp2MProgram`), batch-shardable over a mesh like ERA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.program import (
    SolverProgram,
    StepMask,
    constrain_x,
    step_active,
    trajectory_aux,
)
from repro.core.schedules import NoiseSchedule, timesteps
from repro.core.solver_base import EpsFn, SolverConfig, SolverOutput, step_grid

Array = jax.Array


def _expm1(x):
    return jnp.expm1(x)


def _step1(eps_fn, sched, x, t, t_next):
    """DPM-Solver-1 (== DDIM in lambda space). 1 NFE."""
    lam_t, lam_n = sched.lam(t), sched.lam(t_next)
    h = lam_n - lam_t
    e = eps_fn(x, t)
    return (sched.alpha(t_next) / sched.alpha(t)) * x - sched.sigma(
        t_next
    ) * _expm1(h) * e


def _step2(eps_fn, sched, x, t, t_next, r1=0.5):
    """DPM-Solver-2 (midpoint). 2 NFE."""
    lam_t, lam_n = sched.lam(t), sched.lam(t_next)
    h = lam_n - lam_t
    s = sched.inv_lam(lam_t + r1 * h)
    e_t = eps_fn(x, t)
    u = (sched.alpha(s) / sched.alpha(t)) * x - sched.sigma(s) * _expm1(
        r1 * h
    ) * e_t
    e_s = eps_fn(u, s)
    x_n = (
        (sched.alpha(t_next) / sched.alpha(t)) * x
        - sched.sigma(t_next) * _expm1(h) * e_t
        - sched.sigma(t_next) / (2.0 * r1) * _expm1(h) * (e_s - e_t)
    )
    return x_n


def _step3(eps_fn, sched, x, t, t_next, r1=1.0 / 3.0, r2=2.0 / 3.0):
    """DPM-Solver-3 (Lu et al. Algorithm 2). 3 NFE."""
    lam_t, lam_n = sched.lam(t), sched.lam(t_next)
    h = lam_n - lam_t
    s1 = sched.inv_lam(lam_t + r1 * h)
    s2 = sched.inv_lam(lam_t + r2 * h)
    a_t = sched.alpha(t)
    e_t = eps_fn(x, t)
    u1 = (sched.alpha(s1) / a_t) * x - sched.sigma(s1) * _expm1(r1 * h) * e_t
    d1 = eps_fn(u1, s1) - e_t
    u2 = (
        (sched.alpha(s2) / a_t) * x
        - sched.sigma(s2) * _expm1(r2 * h) * e_t
        - (sched.sigma(s2) * r2 / r1) * (_expm1(r2 * h) / (r2 * h) - 1.0) * d1
    )
    d2 = eps_fn(u2, s2) - e_t
    x_n = (
        (sched.alpha(t_next) / a_t) * x
        - sched.sigma(t_next) * _expm1(h) * e_t
        - (sched.sigma(t_next) / r2) * (_expm1(h) / h - 1.0) * d2
    )
    return x_n


_STEPS = {1: _step1, 2: _step2, 3: _step3}


def sample_pp2m_scan(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: SolverConfig,
    shardings=None,
    steps: StepMask | None = None,
) -> SolverOutput:
    """DPM-Solver++(2M) (Lu et al. 2022b) — the multistep data-prediction
    variant the paper benchmarks against on Stable Diffusion (Appendix E).

    Works in x0-space: x0_i = (x - sigma eps)/alpha;
      D_i = (1 + 1/(2 r_i)) x0_i - 1/(2 r_i) x0_{i-1},  r_i = h_{i-1}/h_i
      x_{i+1} = (sigma_{i+1}/sigma_i) x_i - alpha_{i+1} expm1(-h_i) D_i
    1 NFE per step (like DDIM/ERA), second order.  The multistep carry is
    ``(x, x0_prev)`` — no history buffer beyond the previous x0 prediction.
    """
    n = config.nfe
    dt = config.solver_dtype
    if steps is None:
        # `timesteps` returns an optimization-barrier'd grid, so these
        # coefficient maps evaluate at runtime — exactly like the
        # step-masked path's maps over runtime StepMask rows
        ts = timesteps(schedule, n, "logsnr", t_end=config.t_end)
        lam = schedule.lam(ts)
        alpha, sigma = schedule.alpha(ts), schedule.sigma(ts)
        grid = step_grid(ts)
    else:
        # per-row grids: coefficients are computed per step from the
        # gathered (B, 1, ..) time columns (like ddim's step-masked path),
        # NOT gathered from a precomputed (B, n+1) map — a full-matrix
        # transcendental evaluation rounds differently at different batch
        # buckets, which would let scheduler batch composition leak
        # last-ulp differences into results
        grid = jnp.arange(n, dtype=jnp.int32)

    x = constrain_x(x_init.astype(dt), shardings)

    def _col(arr, j):
        # row-broadcastable column j of a per-row (B, n+1) coefficient map
        c = jax.lax.dynamic_index_in_dim(arr, j, axis=1, keepdims=False)
        return c.reshape((-1,) + (1,) * (x_init.ndim - 1))

    def step(carry, inp):
        x, x0_prev = carry
        if steps is None:
            i, t_cur, _t_next = inp
            l_i, l_ip1 = lam[i], lam[i + 1]
            l_im1 = lam[jnp.maximum(i - 1, 0)]
            a_i, a_ip1 = alpha[i], alpha[i + 1]
            s_i, s_ip1 = sigma[i], sigma[i + 1]
        else:
            i = inp
            t_cur = _col(steps.ts, i)
            t_ip1 = _col(steps.ts, i + 1)
            t_im1 = _col(steps.ts, jnp.maximum(i - 1, 0))
            l_i, l_ip1 = schedule.lam(t_cur), schedule.lam(t_ip1)
            l_im1 = schedule.lam(t_im1)
            a_i, a_ip1 = schedule.alpha(t_cur), schedule.alpha(t_ip1)
            s_i, s_ip1 = schedule.sigma(t_cur), schedule.sigma(t_ip1)
        e = eps_fn(x, t_cur).astype(dt)
        x0 = (x - s_i.astype(dt) * e) / a_i.astype(dt)
        h = l_ip1 - l_i
        h_prev = l_i - l_im1
        r = h_prev / h
        use_ms = i > 0
        coef = jnp.where(use_ms, 1.0 / (2.0 * jnp.where(use_ms, r, 1.0)), 0.0)
        d = (1.0 + coef).astype(dt) * x0 - coef.astype(dt) * x0_prev
        x_next = (s_ip1 / s_i).astype(dt) * x - (
            a_ip1 * jnp.expm1(-h)
        ).astype(dt) * d
        if steps is not None:
            # spent rows freeze bitwise — including the multistep x0 carry
            # (their padded-grid h is 0, which would NaN the combine)
            act = step_active(steps, i, x.ndim)
            x_next = jnp.where(act, x_next, x)
            x0 = jnp.where(act, x0, x0_prev)
        traj_x = x_next if config.return_trajectory else None
        return (x_next, x0), traj_x

    (x, _), traj_tail = jax.lax.scan(step, (x, jnp.zeros_like(x)), grid)
    aux = trajectory_aux(x_init, traj_tail, config.return_trajectory, dtype=dt)
    return SolverOutput(x0=x.astype(x_init.dtype), nfe=jnp.int32(n), aux=aux)


def sample_pp2m(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: SolverConfig,
) -> SolverOutput:
    return sample_pp2m_scan(eps_fn, x_init, schedule, config)


def _order_plan(nfe: int, max_order: int) -> list[int]:
    """DPM-Solver-fast order sequence (Lu et al. Sec. 3.4)."""
    if max_order == 2:
        k = nfe // 2
        plan = [2] * k
        if nfe % 2:
            plan.append(1)
        return plan
    # max_order == 3
    if nfe % 3 == 0:
        return [3] * (nfe // 3 - 1) + [2, 1]
    if nfe % 3 == 1:
        return [3] * (nfe // 3) + [1]
    return [3] * (nfe // 3) + [2]


def sample(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: SolverConfig,
    order: int = 3,
    fast: bool = True,
) -> SolverOutput:
    """DPM-Solver with an exact NFE budget.

    ``order=2, fast=False`` -> DPM-Solver-2 rows; ``order=3, fast=True`` ->
    DPM-Solver-fast rows of the paper's tables.  Steps are uniform in
    lambda (logSNR), the setting DPM-Solver recommends.
    """
    nfe = config.nfe
    if fast:
        plan = _order_plan(nfe, order)
    else:
        plan = [order] * (nfe // order)
        if nfe % order:
            plan.append(nfe % order)
    n_steps = len(plan)
    # lambda-uniform outer grid over the steps
    ts = timesteps(schedule, n_steps, "logsnr", t_end=config.t_end)

    x = x_init.astype(config.solver_dtype)
    for i, o in enumerate(plan):
        x = _STEPS[o](eps_fn, schedule, x, ts[i], ts[i + 1])
    return SolverOutput(
        x0=x.astype(x_init.dtype), nfe=jnp.int32(sum(plan)), aux={}
    )


class DPMpp2MProgram(SolverProgram):
    name = "dpm_solver_pp2m"

    def validate(self, req, cfg, dp=1):
        super().validate(req, cfg, dp=dp)
        if req.nfe < 2:
            raise ValueError(
                f"dpm_solver_pp2m is a 2-step multistep method whose first "
                f"step is order-1 warmup; it needs nfe >= 2, got "
                f"nfe={req.nfe}"
            )

    def supports_steps(self, cfg):
        return True

    def step_times(self, schedule, nfe, cfg):
        # the pp2m scan pins its grid to logSNR spacing regardless of
        # cfg.scheme — StepMask rows must carry those exact floats
        return timesteps(schedule, nfe, "logsnr", t_end=cfg.t_end)

    def sample_scan(
        self, eps_fn, x_init, buffers, schedule, cfg, shardings=None,
        lengths=None, steps=None,
    ):
        # DPM++(2M)'s multistep combine is elementwise over positions — no
        # solver-side sequence reductions to mask under `lengths`.
        assert not buffers
        return sample_pp2m_scan(
            eps_fn, x_init, schedule, cfg, shardings=shardings, steps=steps
        )


class DPMSolverProgram(SolverProgram):
    """Singlestep DPM-Solver (orders 2/3 + the "fast" mixed-order plan).

    The order plan is static Python, so the "program" is the unrolled XLA
    graph — still one jit compile per (sample-shape, nfe) bucket, still
    row-independent (fusable), just without a scan carry to shard beyond
    the latents themselves."""

    def __init__(self, name: str, order: int, fast: bool):
        self.name = name
        self._sample = functools.partial(sample, order=order, fast=fast)

    def sample_scan(
        self, eps_fn, x_init, buffers, schedule, cfg, shardings=None,
        lengths=None, steps=None,
    ):
        # singlestep DPM updates are elementwise over positions — no
        # solver-side sequence reductions to mask under `lengths`.  The
        # mixed-order plan is Python-unrolled per NFE, so there is no
        # step-masked variant (supports_steps stays False).
        assert not buffers
        assert steps is None, f"{self.name} does not support step masking"
        x = constrain_x(x_init, shardings)
        out = self._sample(eps_fn, x, schedule, cfg)
        return out
