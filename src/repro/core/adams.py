"""Adams-family baselines the paper compares against.

* ``explicit_adams`` — Adams--Bashforth order 4 in eps-space with an
  increasing-order warmup; this is the linear-multistep scheme underlying
  PNDM/FON (paper Eq. 9), 1 NFE/step.
* ``implicit_adams_pece`` — the *traditional* predictor-corrector for
  implicit Adams (Diethelm et al. 2002): AB4 predictor -> evaluate at the
  predicted point -> AM4 corrector -> evaluate at the corrected point
  (stored as history).  2 NFE/step; this is the "implicit Adams" baseline of
  the paper's Fig. 1 / Fig. 7.

The "fixed" ablation of Table 4 (Lagrange predictor with fixed last-k
selection) is :func:`repro.core.era.sample` with ``selection="fixed"``.

Engine notes: both loops are single ``jax.lax.scan`` programs over the
step grid with fixed-capacity eps/t history buffers threaded in as
explicit arguments (:class:`ExplicitAdamsProgram`,
:class:`ImplicitAdamsPECEProgram`) — same shape discipline as ERA — so a
jitting caller donates the buffers and one compile covers a whole
(sample-shape, nfe) bucket, batch-shardable over a mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.program import (
    SolverProgram,
    StepMask,
    constrain_buffers,
    constrain_x,
    step_active,
    step_row_times,
    trajectory_aux,
)
from repro.core.schedules import NoiseSchedule, timesteps
from repro.core.solver_base import (
    EpsFn,
    SolverConfig,
    SolverOutput,
    buffer_append,
    buffer_init,
    ddim_step,
    step_grid,
)

Array = jax.Array

# Adams--Bashforth coefficients by order, applied to (e_i, e_{i-1}, ...).
AB_COEFFS = {
    1: (1.0,),
    2: (3 / 2, -1 / 2),
    3: (23 / 12, -16 / 12, 5 / 12),
    4: (55 / 24, -59 / 24, 37 / 24, -9 / 24),  # paper Eq. 9
}
AM4 = (9 / 24, 19 / 24, -5 / 24, 1 / 24)       # paper Eq. 10/11


def _ab_combine(eps_buf: Array, i: Array, order: int) -> Array:
    """Adams--Bashforth combination of the last `order` stored noises."""
    coeffs = AB_COEFFS[order]
    out = None
    for j, c in enumerate(coeffs):
        e = jax.lax.dynamic_index_in_dim(eps_buf, i - j, 0, keepdims=False)
        out = c * e if out is None else out + c * e
    return out


def _ab_predict(eps_buf: Array, i: Array, order: int) -> Array:
    """AB combine at the best order available at step i (warmup ramps the
    order up instead of burning extra NFE, FON-style)."""
    branches = [lambda _, o=o: _ab_combine(eps_buf, i, o) for o in range(1, order + 1)]
    eff = jnp.minimum(i + 1, order)  # order available at step i
    return jax.lax.switch(eff - 1, branches, None)


def alloc_buffers(
    x: Array, config: SolverConfig, shardings=None, num_steps: int | None = None
) -> tuple[Array, Array]:
    """Fresh eps/t history buffers for an Adams run (``num_steps`` defaults
    to ``config.nfe`` — PECE passes its halved step count).  With
    ``shardings``, the eps buffer is created batch-sharded in place."""
    cap = (config.nfe if num_steps is None else num_steps) + 1
    return buffer_init(x, cap, config.solver_dtype, shardings)


def explicit_adams_scan(
    eps_fn: EpsFn,
    x_init: Array,
    eps_buf: Array,      # (nfe+1, *x.shape) zeros, donatable
    t_buf: Array,        # (nfe+1,) zeros, donatable
    schedule: NoiseSchedule,
    config: SolverConfig,
    order: int = 4,
    shardings=None,
    steps: StepMask | None = None,
) -> SolverOutput:
    """AB-`order` linear multistep in eps-space (PNDM-style), 1 NFE/step."""
    n = config.nfe
    dt = config.solver_dtype
    if eps_buf.shape != (n + 1,) + x_init.shape:
        raise ValueError(
            f"eps buffer shape {eps_buf.shape} != {(n + 1,) + x_init.shape}"
        )
    if steps is None:
        ts = timesteps(schedule, n, config.scheme, t_end=config.t_end)
        t0 = ts[0]
    else:
        t0 = steps.ts[:, 0].reshape((-1,) + (1,) * (x_init.ndim - 1))

    x = constrain_x(x_init.astype(dt), shardings)
    eps_buf, t_buf = constrain_buffers(eps_buf, t_buf, shardings)
    e0 = eps_fn(x, t0).astype(dt)
    eps_buf, t_buf = buffer_append(
        eps_buf, t_buf, jnp.int32(0), e0,
        jnp.float32(0.0) if steps is not None else ts[0],
    )

    def step(carry, inp):
        x, eps_buf, t_buf = carry
        if steps is None:
            i, t_cur, t_next = inp
        else:
            i = inp
            t_cur, t_next = step_row_times(steps, i, x.ndim)
        eps_c = _ab_predict(eps_buf, i, order)
        x_next = ddim_step(schedule, x, eps_c, t_cur, t_next)
        if steps is not None:
            x_next = jnp.where(step_active(steps, i, x.ndim), x_next, x)

        def observe(_):
            e = eps_fn(x_next, t_next).astype(dt)
            if steps is not None:
                # a row's own final step appends zeros, like the exact run
                obs = (i + 1) < steps.active_steps
                e = jnp.where(obs.reshape((-1,) + (1,) * (e.ndim - 1)), e, 0.0)
            return e

        e_new = jax.lax.cond(
            i + 1 < n, observe, lambda _: jnp.zeros_like(x_next), None
        )
        eps_buf2, t_buf2 = buffer_append(
            eps_buf, t_buf, i + 1, e_new,
            jnp.float32(0.0) if steps is not None else t_next,
        )
        traj_x = x_next if config.return_trajectory else None
        return (x_next, eps_buf2, t_buf2), traj_x

    grid = (
        step_grid(ts) if steps is None else jnp.arange(n, dtype=jnp.int32)
    )
    (x, eps_buf, t_buf), traj_tail = jax.lax.scan(
        step, (x, eps_buf, t_buf), grid
    )
    aux = trajectory_aux(x_init, traj_tail, config.return_trajectory, dtype=dt)
    return SolverOutput(x0=x.astype(x_init.dtype), nfe=jnp.int32(n), aux=aux)


def explicit_adams_sample(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: SolverConfig,
    order: int = 4,
) -> SolverOutput:
    eps_buf, t_buf = alloc_buffers(x_init.astype(config.solver_dtype), config)
    return explicit_adams_scan(
        eps_fn, x_init, eps_buf, t_buf, schedule, config, order=order
    )


def pece_num_steps(nfe: int) -> int:
    """PECE spends 2 NFE per step: budget B buys B//2 steps."""
    return max(nfe // 2, 1)


def implicit_adams_pece_scan(
    eps_fn: EpsFn,
    x_init: Array,
    eps_buf: Array,      # (n_steps+1, *x.shape) zeros, donatable
    t_buf: Array,        # (n_steps+1,) zeros, donatable
    schedule: NoiseSchedule,
    config: SolverConfig,
    shardings=None,
    steps: StepMask | None = None,
) -> SolverOutput:
    """Traditional PECE implicit Adams (2 NFE/step).

    With an NFE budget B the solver takes B//2 steps.  The history buffer
    stores evaluations at *corrected* points.  ``steps.active_steps``
    counts PECE steps (not NFE) — the program's ``steps_for_nfe`` does the
    halving.
    """
    n_steps = pece_num_steps(config.nfe)
    dt = config.solver_dtype
    if eps_buf.shape != (n_steps + 1,) + x_init.shape:
        raise ValueError(
            f"eps buffer shape {eps_buf.shape} != "
            f"{(n_steps + 1,) + x_init.shape}"
        )
    if steps is None:
        ts = timesteps(schedule, n_steps, config.scheme, t_end=config.t_end)
        t0 = ts[0]
    else:
        t0 = steps.ts[:, 0].reshape((-1,) + (1,) * (x_init.ndim - 1))

    x = constrain_x(x_init.astype(dt), shardings)
    eps_buf, t_buf = constrain_buffers(eps_buf, t_buf, shardings)
    e0 = eps_fn(x, t0).astype(dt)
    eps_buf, t_buf = buffer_append(
        eps_buf, t_buf, jnp.int32(0), e0,
        jnp.float32(0.0) if steps is not None else ts[0],
    )

    def step(carry, inp):
        x, eps_buf, t_buf = carry
        if steps is None:
            i, t_cur, t_next = inp
        else:
            i = inp
            t_cur, t_next = step_row_times(steps, i, x.ndim)
        # P: AB predictor at the best order available
        eps_p = _ab_predict(eps_buf, i, 4)
        x_pred = ddim_step(schedule, x, eps_p, t_cur, t_next)
        # E: evaluate at the predicted point
        e_bar = eps_fn(x_pred, t_next).astype(dt)
        # C: AM4 corrector (falls back to lower effective order via e-history
        # zeros only in the first 2 steps, where AB order is low anyway)
        e_i = jax.lax.dynamic_index_in_dim(eps_buf, i, 0, keepdims=False)
        e_im1 = jax.lax.dynamic_index_in_dim(
            eps_buf, jnp.maximum(i - 1, 0), 0, keepdims=False
        )
        e_im2 = jax.lax.dynamic_index_in_dim(
            eps_buf, jnp.maximum(i - 2, 0), 0, keepdims=False
        )
        c0, c1, c2, c3 = AM4
        eps_c = c0 * e_bar + c1 * e_i + c2 * e_im1 + c3 * e_im2
        # trapezoid fallback while history is short
        eps_c = jnp.where(i >= 2, eps_c, 0.5 * (e_bar + e_i))
        x_next = ddim_step(schedule, x, eps_c, t_cur, t_next)
        if steps is not None:
            x_next = jnp.where(step_active(steps, i, x.ndim), x_next, x)

        # E: evaluate at the corrected point for the history buffer
        def observe(_):
            e = eps_fn(x_next, t_next).astype(dt)
            if steps is not None:
                obs = (i + 1) < steps.active_steps
                e = jnp.where(obs.reshape((-1,) + (1,) * (e.ndim - 1)), e, 0.0)
            return e

        e_new = jax.lax.cond(
            i + 1 < n_steps, observe, lambda _: jnp.zeros_like(x_next), None
        )
        eps_buf2, t_buf2 = buffer_append(
            eps_buf, t_buf, i + 1, e_new,
            jnp.float32(0.0) if steps is not None else t_next,
        )
        traj_x = x_next if config.return_trajectory else None
        return (x_next, eps_buf2, t_buf2), traj_x

    grid = (
        step_grid(ts)
        if steps is None
        else jnp.arange(n_steps, dtype=jnp.int32)
    )
    (x, eps_buf, t_buf), traj_tail = jax.lax.scan(
        step, (x, eps_buf, t_buf), grid
    )
    aux = trajectory_aux(x_init, traj_tail, config.return_trajectory, dtype=dt)
    return SolverOutput(
        x0=x.astype(x_init.dtype), nfe=jnp.int32(2 * n_steps - 1), aux=aux
    )


def implicit_adams_pece_sample(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: SolverConfig,
) -> SolverOutput:
    eps_buf, t_buf = alloc_buffers(
        x_init.astype(config.solver_dtype),
        config,
        num_steps=pece_num_steps(config.nfe),
    )
    return implicit_adams_pece_scan(
        eps_fn, x_init, eps_buf, t_buf, schedule, config
    )


class ExplicitAdamsProgram(SolverProgram):
    name = "explicit_adams"

    def num_buffers(self, cfg):
        return 2

    def supports_steps(self, cfg):
        return True

    def alloc_buffers(self, x_like, cfg, shardings=None):
        return alloc_buffers(x_like.astype(cfg.solver_dtype), cfg, shardings)

    def sample_scan(
        self, eps_fn, x_init, buffers, schedule, cfg, shardings=None,
        lengths=None, steps=None,
    ):
        # AB4's combine is elementwise over positions — no solver-side
        # sequence reductions to mask under `lengths`.
        eps_buf, t_buf = buffers
        return explicit_adams_scan(
            eps_fn, x_init, eps_buf, t_buf, schedule, cfg,
            shardings=shardings, steps=steps,
        )


class ImplicitAdamsPECEProgram(SolverProgram):
    name = "implicit_adams_pece"

    def num_buffers(self, cfg):
        return 2

    def supports_steps(self, cfg):
        return True

    def steps_for_nfe(self, nfe, cfg):
        # StepMask.active_steps counts PECE steps: 2 NFE buys one
        return pece_num_steps(nfe)

    def validate(self, req, cfg, dp=1):
        super().validate(req, cfg, dp=dp)
        if req.nfe < 2:
            raise ValueError(
                f"implicit_adams_pece spends 2 NFE per PECE step, so its "
                f"budget must be >= 2; got nfe={req.nfe}"
            )

    def alloc_buffers(self, x_like, cfg, shardings=None):
        return alloc_buffers(
            x_like.astype(cfg.solver_dtype),
            cfg,
            shardings,
            num_steps=pece_num_steps(cfg.nfe),
        )

    def sample_scan(
        self, eps_fn, x_init, buffers, schedule, cfg, shardings=None,
        lengths=None, steps=None,
    ):
        # PECE's predictor/corrector math is elementwise over positions —
        # no solver-side sequence reductions to mask under `lengths`.
        eps_buf, t_buf = buffers
        return implicit_adams_pece_scan(
            eps_fn, x_init, eps_buf, t_buf, schedule, cfg,
            shardings=shardings, steps=steps,
        )
