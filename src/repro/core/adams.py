"""Adams-family baselines the paper compares against.

* ``explicit_adams`` — Adams--Bashforth order 4 in eps-space with an
  increasing-order warmup; this is the linear-multistep scheme underlying
  PNDM/FON (paper Eq. 9), 1 NFE/step.
* ``implicit_adams_pece`` — the *traditional* predictor-corrector for
  implicit Adams (Diethelm et al. 2002): AB4 predictor -> evaluate at the
  predicted point -> AM4 corrector -> evaluate at the corrected point
  (stored as history).  2 NFE/step; this is the "implicit Adams" baseline of
  the paper's Fig. 1 / Fig. 7.

The "fixed" ablation of Table 4 (Lagrange predictor with fixed last-k
selection) is :func:`repro.core.era.sample` with ``selection="fixed"``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.schedules import NoiseSchedule, timesteps
from repro.core.solver_base import (
    EpsFn,
    SolverConfig,
    SolverOutput,
    buffer_append,
    buffer_init,
    ddim_step,
    trajectory_append,
    trajectory_init,
)

Array = jax.Array

# Adams--Bashforth coefficients by order, applied to (e_i, e_{i-1}, ...).
AB_COEFFS = {
    1: (1.0,),
    2: (3 / 2, -1 / 2),
    3: (23 / 12, -16 / 12, 5 / 12),
    4: (55 / 24, -59 / 24, 37 / 24, -9 / 24),  # paper Eq. 9
}
AM4 = (9 / 24, 19 / 24, -5 / 24, 1 / 24)       # paper Eq. 10/11


def _ab_combine(eps_buf: Array, i: Array, order: int) -> Array:
    """Adams--Bashforth combination of the last `order` stored noises."""
    coeffs = AB_COEFFS[order]
    out = None
    for j, c in enumerate(coeffs):
        e = jax.lax.dynamic_index_in_dim(eps_buf, i - j, 0, keepdims=False)
        out = c * e if out is None else out + c * e
    return out


def explicit_adams_sample(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: SolverConfig,
    order: int = 4,
) -> SolverOutput:
    """AB-`order` linear multistep in eps-space (PNDM-style), 1 NFE/step.

    Warmup uses increasing order (1,2,3) instead of PNDM's Runge--Kutta so
    no extra NFE are burned (FON-style)."""
    n = config.nfe
    ts = timesteps(schedule, n, config.scheme, t_end=config.t_end)
    dt = config.solver_dtype

    x = x_init.astype(dt)
    eps_buf, t_buf = buffer_init(x, n + 1, dt)
    e0 = eps_fn(x, ts[0]).astype(dt)
    eps_buf, t_buf = buffer_append(eps_buf, t_buf, jnp.int32(0), e0, ts[0])
    traj = trajectory_init(x, n, config.return_trajectory)

    def body(i, carry):
        x, eps_buf, t_buf, traj = carry
        t_cur, t_next = ts[i], ts[i + 1]

        branches = []
        for o in range(1, order + 1):
            branches.append(lambda _, o=o: _ab_combine(eps_buf, i, o))
        eff = jnp.minimum(i + 1, order)  # order available at step i
        eps_c = jax.lax.switch(eff - 1, branches, None)

        x_next = ddim_step(schedule, x, eps_c, t_cur, t_next)

        def observe(_):
            return eps_fn(x_next, t_next).astype(dt)

        e_new = jax.lax.cond(
            i + 1 < n, observe, lambda _: jnp.zeros_like(x_next), None
        )
        eps_buf2, t_buf2 = buffer_append(eps_buf, t_buf, i + 1, e_new, t_next)
        traj = trajectory_append(traj, i + 1, x_next)
        return (x_next, eps_buf2, t_buf2, traj)

    x, eps_buf, t_buf, traj = jax.lax.fori_loop(0, n, body, (x, eps_buf, t_buf, traj))
    aux = {"trajectory": traj} if traj is not None else {}
    return SolverOutput(x0=x.astype(x_init.dtype), nfe=jnp.int32(n), aux=aux)


def implicit_adams_pece_sample(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: SolverConfig,
) -> SolverOutput:
    """Traditional PECE implicit Adams (2 NFE/step).

    With an NFE budget B the solver takes B//2 steps.  The history buffer
    stores evaluations at *corrected* points.
    """
    n_steps = max(config.nfe // 2, 1)
    ts = timesteps(schedule, n_steps, config.scheme, t_end=config.t_end)
    dt = config.solver_dtype

    x = x_init.astype(dt)
    eps_buf, t_buf = buffer_init(x, n_steps + 1, dt)
    e0 = eps_fn(x, ts[0]).astype(dt)
    eps_buf, t_buf = buffer_append(eps_buf, t_buf, jnp.int32(0), e0, ts[0])
    traj = trajectory_init(x, n_steps, config.return_trajectory)

    def body(i, carry):
        x, eps_buf, t_buf, traj = carry
        t_cur, t_next = ts[i], ts[i + 1]

        # P: AB predictor at the best order available
        branches = [lambda _, o=o: _ab_combine(eps_buf, i, o) for o in (1, 2, 3, 4)]
        eff = jnp.minimum(i + 1, 4)
        eps_p = jax.lax.switch(eff - 1, branches, None)
        x_pred = ddim_step(schedule, x, eps_p, t_cur, t_next)
        # E: evaluate at the predicted point
        e_bar = eps_fn(x_pred, t_next).astype(dt)
        # C: AM4 corrector (falls back to lower effective order via e-history
        # zeros only in the first 2 steps, where AB order is low anyway)
        e_i = jax.lax.dynamic_index_in_dim(eps_buf, i, 0, keepdims=False)
        e_im1 = jax.lax.dynamic_index_in_dim(
            eps_buf, jnp.maximum(i - 1, 0), 0, keepdims=False
        )
        e_im2 = jax.lax.dynamic_index_in_dim(
            eps_buf, jnp.maximum(i - 2, 0), 0, keepdims=False
        )
        c0, c1, c2, c3 = AM4
        eps_c = c0 * e_bar + c1 * e_i + c2 * e_im1 + c3 * e_im2
        # trapezoid fallback while history is short
        eps_c = jnp.where(i >= 2, eps_c, 0.5 * (e_bar + e_i))
        x_next = ddim_step(schedule, x, eps_c, t_cur, t_next)
        # E: evaluate at the corrected point for the history buffer
        def observe(_):
            return eps_fn(x_next, t_next).astype(dt)

        e_new = jax.lax.cond(
            i + 1 < n_steps, observe, lambda _: jnp.zeros_like(x_next), None
        )
        eps_buf2, t_buf2 = buffer_append(eps_buf, t_buf, i + 1, e_new, t_next)
        traj = trajectory_append(traj, i + 1, x_next)
        return (x_next, eps_buf2, t_buf2, traj)

    x, eps_buf, t_buf, traj = jax.lax.fori_loop(
        0, n_steps, body, (x, eps_buf, t_buf, traj)
    )
    aux = {"trajectory": traj} if traj is not None else {}
    return SolverOutput(
        x0=x.astype(x_init.dtype), nfe=jnp.int32(2 * n_steps - 1), aux=aux
    )
