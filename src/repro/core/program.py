"""Solver programs — the uniform compiled-sampling contract of the engine.

A :class:`SolverProgram` is what the serving stack knows about a solver.
Every registry solver (ERA and every baseline the paper compares against)
implements the same surface, so `repro.serving.FusedExecutor` can fuse,
shard, donate buffers for, and route requests to *any* solver without
solver-specific branches:

* ``alloc_buffers(x_like, cfg, shardings)`` — fixed-capacity history
  buffers (the Lagrange/Adams eps+t buffers), allocated outside the jitted
  program so the caller can donate them (``donate_argnums``) and XLA
  updates them in place across the whole sampling scan.  Solvers without
  history state return ``()``.  ``abstract_buffers`` is the
  ``ShapeDtypeStruct`` mirror ahead-of-time compilation lowers against.
* ``sample_scan(eps_fn, x_init, buffers, schedule, cfg, shardings)`` — the
  single-``lax.scan``(-or-unrolled) XLA program over the step grid.  One
  jit compile covers a whole (sample-shape, nfe) bucket.  Carry
  initialization lives inside (it may spend an NFE on the first
  observation), so there is no separate ``init_carry`` hook.
* ``carry_pspecs`` / ``carry_shardings`` — mesh placement for the scan
  carry (latents batch-sharded over the data axes, history buffers
  batch-sharded on axis 1, time grid replicated), derived from
  ``per_sample_state`` so per-sample solver state shards with its rows.
* ``fusable(cfg)`` / ``validate(req, cfg, dp)`` — request policy: can
  strangers (and pad rows) share a batch under this config, and which
  (batch, nfe) requests are legal (ERA's ``nfe >= k``, PECE's 2-NFE/step
  budget, DPM++(2M)'s multistep warmup).  ``req`` is duck-typed (needs
  ``.batch`` and ``.nfe``) so core stays import-free of the serving layer.
* ``scope_aux(aux, off, batch, seq_len=...)`` + ``aux_row_axes`` /
  ``aux_seq_axes`` — aux-scoping metadata: which diagnostics carry a
  padded-batch axis and which carry a padded-sequence axis, so a
  co-batched request sees only its own rows and valid positions (no
  batch-mate/tenant, pad-row, or pad-position leakage).
* ``supports_lengths(cfg)`` + the ``lengths`` argument of ``sample_scan``
  — the length-mask channel for mixed-seq-len fusion: the serving engine
  right-pads each request's sample from its ``seq_len`` to a shared seq
  bucket and passes the per-row valid lengths through the compiled
  program.  A program that supports lengths guarantees pad positions can
  never change a valid position's math (elementwise solvers get this for
  free; ERA masks its ERS error norms so a pad token can never flip a
  Lagrange-basis selection).
* ``pre_compile(cfg)`` — eager hook consulted before a caller jits the
  program (ERA uses it to run the fused-kernel parity probe, which cannot
  execute inside a jit trace).

Concrete programs live next to their solver math (``DDIMProgram`` in
``ddim.py``, ...) and are registered in :mod:`repro.core.registry`.
"""

from __future__ import annotations

from typing import Any, Mapping, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.schedules import NoiseSchedule, timesteps
from repro.core.solver_base import EpsFn, SolverConfig, SolverOutput

Array = jax.Array


class StepMask(NamedTuple):
    """The mixed-NFE mask channel: per-row step activity for a batch whose
    rows run different step counts inside one compiled scan.

    The scan itself always runs the bucket's full ``n_steps`` iterations;
    a row whose request needs fewer steps goes inert once its own count is
    spent — step ``i`` is **active** for row ``r`` iff
    ``i < active_steps[r]``, and an inactive step must leave that row's
    entire carry (latents, history buffers, per-sample solver state)
    bitwise unchanged.  Each row also carries its *own* time grid: row
    ``r``'s real grid (``step_times`` for its exact NFE) occupies
    ``ts[r, : active_steps[r] + 1]``, with the terminal time repeated
    through the padded tail so inactive steps still see finite times.
    Both arrays are built host-side by the serving executor with the same
    ``timesteps`` call an exact-shape run uses, which is what makes the
    active prefix of a padded row bitwise identical to the unpadded run.
    """

    #: (B,) int32 — per-row count of real solver steps
    active_steps: Array
    #: (B, n_steps + 1) float32 — per-row time grids, terminal-padded
    ts: Array


def step_active(steps: StepMask, i: Array, x_ndim: int = 3) -> Array:
    """Per-row activity predicate for scan step ``i``, broadcastable
    against ``(B,) + trailing`` carries: shape ``(B,) + (1,) * (x_ndim-1)``."""
    act = i < steps.active_steps
    return act.reshape(act.shape + (1,) * (x_ndim - 1))


def step_row_times(steps: StepMask, i: Array, x_ndim: int = 3):
    """Row times ``(t_cur, t_next)`` for scan step ``i`` under a step
    mask, shaped ``(B,) + (1,) * (x_ndim - 1)`` so schedule coefficients
    broadcast per row exactly like the scalar-time fast path."""
    trail = (1,) * (x_ndim - 1)
    t_cur = jax.lax.dynamic_index_in_dim(steps.ts, i, axis=1, keepdims=False)
    t_next = jax.lax.dynamic_index_in_dim(
        steps.ts, i + 1, axis=1, keepdims=False
    )
    return (
        t_cur.reshape(t_cur.shape + trail),
        t_next.reshape(t_next.shape + trail),
    )


class SolverProgram:
    """Base solver program: a fusable, bufferless, batch-row-independent
    solver.  Subclasses override the hooks their solver needs."""

    #: registry name (set by each concrete program)
    name: str = ""
    #: config dataclass this program consumes
    config_cls: type[SolverConfig] = SolverConfig
    #: aux keys whose value carries the padded batch on the given axis
    aux_row_axes: Mapping[str, int] = {"trajectory": 1}
    #: aux keys whose value carries the padded sequence on the given axis
    aux_seq_axes: Mapping[str, int] = {"trajectory": 2}
    #: aux keys whose value is stacked over scan steps on the given axis
    #: (scoped to a request's real step count under NFE bucketing)
    aux_step_axes: Mapping[str, int] = {"trajectory": 0}

    # ---- configs ---------------------------------------------------------
    def default_config(self, **kw) -> SolverConfig:
        """The paper-default config (what ``core.default_config`` returns)."""
        return self.config_cls(**kw)

    def engine_config(self) -> SolverConfig:
        """The serving-engine default config.  Programs whose paper default
        couples batch rows override this with an isolation-safe variant
        (ERA turns on per-sample ERS)."""
        return self.config_cls()

    # ---- request policy --------------------------------------------------
    def fusable(self, cfg: SolverConfig) -> bool:
        """Can strangers (and pad rows) share a fused batch under ``cfg``?
        True whenever every batch row's math reads only its own row."""
        return True

    def per_sample_state(self, cfg: SolverConfig) -> bool:
        """Does the scan carry per-sample ``(B,)``-shaped solver state that
        should shard with its rows (ERA's per-sample delta_eps)?"""
        return False

    def supports_lengths(self, cfg: SolverConfig) -> bool:
        """Can this program run a right-padded mixed-seq-len batch with a
        per-row ``lengths`` vector such that every valid position's math is
        exactly what an unpadded run would compute?

        True is correct whenever the solver's own math is elementwise over
        positions (DDIM / Adams / DPM updates touch each position
        independently, so a pad position can never leak into a valid one —
        the *denoiser* mask is the engine's responsibility).  A program
        whose per-step math reduces over the sequence (ERA's ERS error
        norm) must mask that reduction to return True."""
        return True

    def supports_steps(self, cfg: SolverConfig) -> bool:
        """Can this program run a mixed-NFE batch under a :class:`StepMask`
        — scanning to a bucketed max step count with per-row activity —
        such that a row's active steps compute exactly what an exact-NFE
        run would, and its inactive steps leave its carry bitwise
        unchanged?  Requires the scan form (Python-unrolled solvers whose
        step *plan* depends on the NFE, like dpm_solver_fast, cannot) plus
        per-row times threaded through every schedule coefficient."""
        return False

    def steps_for_nfe(self, nfe: int, cfg: SolverConfig) -> int:
        """How many scan steps a request with NFE budget ``nfe`` runs
        (PECE spends 2 NFE per step; the adaptive program turns the budget
        into an iteration cap).  This is the unit ``StepMask.active_steps``
        counts in — scan steps, not NFE."""
        return nfe

    def step_times(
        self, schedule: NoiseSchedule, nfe: int, cfg: SolverConfig
    ) -> Array:
        """The exact time grid a request with budget ``nfe`` steps through
        — ``(steps_for_nfe(nfe) + 1,)`` decreasing.  The serving executor
        builds each row of ``StepMask.ts`` with this hook so a padded
        row's grid prefix is the very floats the unpadded run uses;
        programs that pin a scheme in their scan (DPM++'s logsnr grid)
        override it to match."""
        return timesteps(
            schedule, self.steps_for_nfe(nfe, cfg), cfg.scheme,
            t_end=cfg.t_end,
        )

    def validate(self, req: Any, cfg: SolverConfig, dp: int = 1) -> None:
        """Reject an illegal request at submit time.  ``req`` needs
        ``.batch`` and ``.nfe``.  Base rule: a non-fusable config runs
        unpadded (exact size), so on a mesh its batch must split evenly
        over the data axes."""
        if req.nfe < 1:
            raise ValueError(f"nfe must be >= 1, got {req.nfe}")
        if not self.fusable(cfg) and dp > 1 and req.batch % dp:
            raise ValueError(
                f"{self.name} requests under this config are not fusable and "
                f"run unpadded, so on a mesh their batch must be a multiple "
                f"of the data-parallel size ({dp}); got batch={req.batch}."
            )

    # ---- buffers / placement --------------------------------------------
    def num_buffers(self, cfg: SolverConfig) -> int:
        """How many donatable buffer arrays ``alloc_buffers`` returns
        (static per config — the jit donate_argnums depend on it)."""
        return 0

    def alloc_buffers(
        self, x_like: Array, cfg: SolverConfig, shardings=None
    ) -> tuple[Array, ...]:
        """Fresh donatable history buffers for one sampling run (empty for
        history-free solvers).  With ``shardings``, buffers are created
        batch-sharded in place instead of materialized on one device."""
        return ()

    def abstract_buffers(
        self, x_like, cfg: SolverConfig, shardings=None
    ) -> tuple[jax.ShapeDtypeStruct, ...]:
        """Abstract (``ShapeDtypeStruct``) mirror of :meth:`alloc_buffers`
        — what an ahead-of-time caller lowers against instead of
        materializing zero buffers.  ``x_like`` may itself be abstract.

        Derived by shape-evaluating the unsharded allocation, so programs
        never implement it twice.  With ``shardings``, the buffers carry
        the same placement :meth:`alloc_buffers` commits them to — the
        ``(eps_buf, t_buf)`` convention every buffered program's
        ``buffer_init`` follows; a program with a different buffer layout
        must override this to place them itself."""
        shapes = jax.eval_shape(
            lambda x: self.alloc_buffers(x, cfg, None), x_like
        )
        if not shapes:
            return ()
        if shardings is None:
            return tuple(
                jax.ShapeDtypeStruct(s.shape, s.dtype) for s in shapes
            )
        placed = (shardings.eps_buf, shardings.t_buf)
        if len(shapes) != len(placed):
            raise NotImplementedError(
                f"{type(self).__name__} allocates {len(shapes)} buffers, "
                f"not the (eps_buf, t_buf) pair the base abstract_buffers "
                f"knows how to place — override abstract_buffers"
            )
        return tuple(
            jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h)
            for s, h in zip(shapes, placed)
        )

    def carry_pspecs(self, cfg: SolverConfig, mesh, *, batch=None, x_ndim=3):
        """PartitionSpecs for this program's scan carry on ``mesh``."""
        from repro.parallel.sharding import solver_carry_pspecs

        return solver_carry_pspecs(
            mesh, self, cfg, batch=batch, x_ndim=x_ndim
        )

    def carry_shardings(self, cfg: SolverConfig, mesh, *, batch=None, x_ndim=3):
        """``carry_pspecs`` bound to ``mesh`` as NamedShardings — what
        ``sample_scan`` takes as its ``shardings`` argument."""
        from repro.parallel.sharding import solver_carry_shardings

        return solver_carry_shardings(
            mesh, self, cfg, batch=batch, x_ndim=x_ndim
        )

    # ---- compiled entry --------------------------------------------------
    def pre_compile(self, cfg: SolverConfig) -> None:
        """Eager hook run before a caller jits ``sample_scan`` (probes that
        cannot execute mid-trace, e.g. ERA's fused-kernel parity gate)."""

    def sample_scan(
        self,
        eps_fn: EpsFn,
        x_init: Array,
        buffers: tuple[Array, ...],
        schedule: NoiseSchedule,
        cfg: SolverConfig,
        shardings=None,
        lengths: Array | None = None,
        steps: StepMask | None = None,
    ) -> SolverOutput:
        """The solver loop as one XLA program, with ``buffers`` threaded in
        explicitly so a jitting caller can donate them.

        ``lengths`` is the mixed-seq-len mask channel: a per-row ``(B,)``
        int32 vector of valid sequence lengths for a right-padded batch
        (None = every position valid).  Programs whose math is elementwise
        over positions may ignore it; programs with sequence reductions
        must mask them (see :meth:`supports_lengths`).

        ``steps`` is the mixed-NFE mask channel (see :class:`StepMask`):
        when given, the scan runs ``cfg.nfe``'s bucketed step count, each
        row reads its times from its own ``steps.ts`` row, and a row's
        carry freezes bitwise once ``i >= steps.active_steps[row]``.  Only
        programs returning True from :meth:`supports_steps` receive it."""
        raise NotImplementedError

    def sample(
        self,
        eps_fn: EpsFn,
        x_init: Array,
        schedule: NoiseSchedule,
        cfg: SolverConfig,
    ) -> SolverOutput:
        """Self-contained entry: allocates buffers, then runs the program
        (the ``get_solver(name)(...)`` back-compat surface)."""
        return self.sample_scan(
            eps_fn, x_init, self.alloc_buffers(x_init, cfg), schedule, cfg
        )

    # ---- aux scoping -----------------------------------------------------
    def scope_aux(
        self,
        aux: dict,
        off: int,
        batch: int,
        seq_len: int | None = None,
        n_steps: int | None = None,
        padded_steps: int | None = None,
    ) -> dict:
        """Scope solver diagnostics to one request's rows inside a fused
        padded batch, per :attr:`aux_row_axes` — and, for a seq-bucketed
        batch, to the request's valid positions per :attr:`aux_seq_axes`
        (``seq_len`` = the request's unpadded length; None = the batch ran
        at the request's exact shape).  A co-batched request must see only
        its own rows and positions — not its batch-mates' (tenant
        isolation), not the pad rows, and not the pad positions.

        Under NFE bucketing the scan ran ``padded_steps`` iterations but
        this request only took ``n_steps`` real ones, so every
        :attr:`aux_step_axes` entry drops its ``padded_steps - n_steps``
        inert tail along its step axis (preserving any off-by-one framing
        like the trajectory's initial-state frame)."""
        row_hit = {
            k: ax for k, ax in self.aux_row_axes.items()
            if aux.get(k) is not None
        }
        seq_hit = (
            {}
            if seq_len is None
            else {
                k: ax for k, ax in self.aux_seq_axes.items()
                if aux.get(k) is not None
            }
        )
        pad_steps = (
            0
            if n_steps is None or padded_steps is None
            else padded_steps - n_steps
        )
        step_hit = (
            {}
            if pad_steps <= 0
            else {
                k: ax for k, ax in self.aux_step_axes.items()
                if aux.get(k) is not None
            }
        )
        if not row_hit and not seq_hit and not step_hit:
            return aux
        scoped = dict(aux)
        for key, axis in row_hit.items():
            idx = (slice(None),) * axis + (slice(off, off + batch),)
            scoped[key] = scoped[key][idx]
        for key, axis in seq_hit.items():
            idx = (slice(None),) * axis + (slice(0, seq_len),)
            scoped[key] = scoped[key][idx]
        for key, axis in step_hit.items():
            keep = scoped[key].shape[axis] - pad_steps
            idx = (slice(None),) * axis + (slice(0, keep),)
            scoped[key] = scoped[key][idx]
        return scoped


def constrain_x(x: Array, shardings) -> Array:
    """Pin the latents' sharding inside a program (no-op off-mesh)."""
    if shardings is None:
        return x
    return jax.lax.with_sharding_constraint(x, shardings.x)


def constrain_buffers(
    eps_buf: Array, t_buf: Array, shardings
) -> tuple[Array, Array]:
    """Pin the eps/t history buffers' shardings (no-op off-mesh)."""
    if shardings is None:
        return eps_buf, t_buf
    return (
        jax.lax.with_sharding_constraint(eps_buf, shardings.eps_buf),
        jax.lax.with_sharding_constraint(t_buf, shardings.t_buf),
    )


def trajectory_aux(
    x_init: Array, traj_tail: Array | None, enabled: bool, dtype=None
) -> dict[str, Array]:
    """Assemble the ``trajectory`` aux from a scan's stacked per-step
    latents (ys), prepending the initial state."""
    if not enabled or traj_tail is None:
        return {}
    x0 = x_init if dtype is None else x_init.astype(dtype)
    return {"trajectory": jnp.concatenate([x0[None], traj_tail], axis=0)}
