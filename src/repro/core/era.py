"""ERA-Solver (the paper's contribution, Algorithm 1).

Implicit-Adams (Adams--Moulton order 4) corrector whose unobserved term is
predicted by a Lagrange interpolation over an error-robustly selected subset
of previously observed network noises.  1 NFE per step (like DDIM), high
order (like implicit Adams), robust to noise-estimation error (the ERS
strategy).

Structure of one step i (i >= k-1; the first k-1 steps are DDIM warmup while
the Lagrange buffer fills):

  1. select bases  tau_{1..k}  via ERS (Eq. 16/17) using delta_eps
  2. predict       eps_bar_{i+1} = L_eps(t_{i+1})            (Eq. 13/14)
  3. correct       eps_ti = (9 eps_bar_{i+1} + 19 eps_i - 5 eps_{i-1}
                             + eps_{i-2}) / 24               (Eq. 11)
  4. x-update      x_{i+1} = DDIM(x_i, eps_ti)               (Eq. 8)
  5. observe       eps_{i+1} = eps_theta(x_{i+1}, t_{i+1})   (1 NFE)
  6. measure       delta_eps = || eps_{i+1} - eps_bar_{i+1} ||_2   (Eq. 15)

The final iteration skips step 5/6 (the sample is finished), so a run with N
steps costs exactly N NFE (1 initial eval + N-1 in-loop evals).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import lagrange
from repro.core.schedules import NoiseSchedule, timesteps
from repro.core.solver_base import (
    EpsFn,
    SolverConfig,
    SolverOutput,
    buffer_append,
    buffer_init,
    ddim_step,
    trajectory_append,
    trajectory_init,
)

Array = jax.Array

# Adams--Moulton order-4 corrector coefficients (paper Eq. 10/11).
AM4 = (9.0 / 24.0, 19.0 / 24.0, -5.0 / 24.0, 1.0 / 24.0)


@dataclasses.dataclass(frozen=True)
class ERAConfig(SolverConfig):
    """ERA-Solver options (defaults follow the paper's main setting)."""

    k: int = 4                     # Lagrange interpolation order
    lam: float = 5.0               # power-scale hyperparameter (Eq. 17)
    selection: str = "ers"         # "ers" | "fixed" | "const"
    const_power: float = 1.0       # used when selection == "const"
    error_norm: str = "global"     # "global" (Eq. 15) | "mean" (per-sample mean)
    use_fused_update: bool = False # route step 2-4 through the Pallas kernel
    # beyond-paper: independent delta_eps + base selection per batch element
    # (the paper shares one scalar across the batch)
    per_sample: bool = False


def _delta_eps(e_obs: Array, e_pred: Array, mode: str) -> Array:
    d = (e_obs - e_pred).astype(jnp.float32)
    if mode == "global":
        return jnp.linalg.norm(d.reshape(-1))
    if mode == "mean":  # per-sample L2, averaged — batch-size invariant
        return jnp.mean(jnp.sqrt(jnp.sum(d.reshape(d.shape[0], -1) ** 2, -1)))
    raise ValueError(f"unknown error_norm {mode!r}")


def _delta_eps_batch(e_obs: Array, e_pred: Array) -> Array:
    """Per-sample L2 errors, (B,)."""
    d = (e_obs - e_pred).astype(jnp.float32)
    return jnp.sqrt(jnp.sum(d.reshape(d.shape[0], -1) ** 2, -1))


def era_combine(
    eps_sel: Array,      # (k, *x) selected buffer noises
    t_sel: Array,        # (k,) their times
    e_hist: Array,       # (3, *x) eps at steps i, i-1, i-2
    t_next: Array,
) -> tuple[Array, Array]:
    """Predictor + corrector combine: returns (eps_bar_next, eps_corr).

    Kept as a standalone function so the Pallas fused kernel
    (repro.kernels.era_update) can be validated against it and swapped in.
    """
    eps_bar = lagrange.interpolate(eps_sel, t_sel, t_next)
    c0, c1, c2, c3 = AM4
    eps_corr = c0 * eps_bar + c1 * e_hist[0] + c2 * e_hist[1] + c3 * e_hist[2]
    return eps_bar, eps_corr


def sample(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: ERAConfig,
) -> SolverOutput:
    n = config.nfe
    k = config.k
    if n < k:
        raise ValueError(f"ERA-Solver needs nfe >= k ({n} < {k})")
    ts = timesteps(schedule, n, config.scheme, t_end=config.t_end)
    dt = config.solver_dtype

    if config.use_fused_update:
        from repro.kernels import ops as _kops  # deferred; optional dep

        combine = functools.partial(_kops.era_combine, am4=AM4)
    else:
        combine = era_combine

    x = x_init.astype(dt)
    eps_buf, t_buf = buffer_init(x, n + 1, dt)
    # Alg. 1 line 2/3: delta_eps initialized to lambda (power = 1, uniform
    # selection); initial observation appended at index 0.
    e0 = eps_fn(x, ts[0]).astype(dt)
    eps_buf, t_buf = buffer_append(eps_buf, t_buf, jnp.int32(0), e0, ts[0])
    delta_eps = (
        jnp.full((x.shape[0],), config.lam, jnp.float32)
        if config.per_sample
        else jnp.float32(config.lam)
    )
    traj = trajectory_init(x, n, config.return_trajectory)
    de_hist = jnp.zeros((n,), jnp.float32)  # Fig. 3 diagnostic

    def warm_branch(ops):
        x, eps_buf, t_buf, de, i, t_cur, t_next = ops
        e_cur = jax.lax.dynamic_index_in_dim(eps_buf, i, 0, keepdims=False)
        x_next = ddim_step(schedule, x, e_cur, t_cur, t_next)
        return x_next, e_cur  # prediction placeholder: the DDIM-held noise

    def main_branch(ops):
        x, eps_buf, t_buf, de, i, t_cur, t_next = ops
        e_hist = jnp.stack(
            [
                jax.lax.dynamic_index_in_dim(eps_buf, i - j, 0, keepdims=False)
                for j in range(3)
            ]
        )
        if config.per_sample:
            # beyond-paper: each batch element selects its own bases from
            # its own measured error
            tau = jax.vmap(
                lambda d: lagrange.select_bases(
                    i, k, d, config.lam, config.selection, config.const_power
                )
            )(de)                                            # (B, k)
            t_sel = jnp.take(t_buf, tau, axis=0)             # (B, k)
            # per-sample gather from the (cap, B, ...) buffer
            eps_sel = jax.vmap(
                lambda tau_b, buf_b: jnp.take(buf_b, tau_b, axis=0),
                in_axes=(0, 1),
                out_axes=1,
            )(tau, eps_buf)                                  # (k, B, ...)
            w = jax.vmap(lagrange.lagrange_weights, in_axes=(0, None))(
                t_sel, t_next
            )                                                # (B, k)
            wb = w.T.reshape((k,) + (eps_sel.shape[1],) + (1,) * (eps_sel.ndim - 2))
            eps_bar = jnp.sum(wb.astype(eps_sel.dtype) * eps_sel, axis=0)
            c0, c1, c2, c3 = AM4
            eps_corr = (
                c0 * eps_bar + c1 * e_hist[0] + c2 * e_hist[1] + c3 * e_hist[2]
            )
        else:
            tau = lagrange.select_bases(
                i, k, de, config.lam, config.selection, config.const_power
            )
            t_sel = jnp.take(t_buf, tau, axis=0)
            eps_sel = jnp.take(eps_buf, tau, axis=0)
            eps_bar, eps_corr = combine(eps_sel, t_sel, e_hist, t_next)
        x_next = ddim_step(schedule, x, eps_corr, t_cur, t_next)
        return x_next, eps_bar

    def body(i, carry):
        x, eps_buf, t_buf, de, traj, de_hist = carry
        t_cur, t_next = ts[i], ts[i + 1]
        ops = (x, eps_buf, t_buf, de, i, t_cur, t_next)
        x_next, eps_bar = jax.lax.cond(i < k - 1, warm_branch, main_branch, ops)

        # Observe eps at the new point — except on the final step, whose
        # x_next is the output (keeps total cost at exactly `nfe` evals).
        def observe(_):
            e_new = eps_fn(x_next, t_next).astype(dt)
            if config.per_sample:
                de_new = _delta_eps_batch(e_new, eps_bar)
            else:
                de_new = _delta_eps(e_new, eps_bar, config.error_norm)
            return e_new, de_new

        def skip(_):
            return jnp.zeros_like(x_next), de

        e_new, de_new = jax.lax.cond(i + 1 < n, observe, skip, None)
        # Alg. 1 line 16: delta_eps only updates once predictions are real.
        de = jnp.where(i >= k - 1, de_new, de)
        de_hist = de_hist.at[i].set(jnp.mean(de))
        eps_buf, t_buf = buffer_append(eps_buf, t_buf, i + 1, e_new, t_next)
        traj = trajectory_append(traj, i + 1, x_next)
        return (x_next, eps_buf, t_buf, de, traj, de_hist)

    x, eps_buf, t_buf, delta_eps, traj, de_hist = jax.lax.fori_loop(
        0, n, body, (x, eps_buf, t_buf, delta_eps, traj, de_hist)
    )
    aux: dict[str, Any] = {"delta_eps_history": de_hist}
    if traj is not None:
        aux["trajectory"] = traj
    return SolverOutput(x0=x.astype(x_init.dtype), nfe=jnp.int32(n), aux=aux)
