"""ERA-Solver (the paper's contribution, Algorithm 1).

Implicit-Adams (Adams--Moulton order 4) corrector whose unobserved term is
predicted by a Lagrange interpolation over an error-robustly selected subset
of previously observed network noises.  1 NFE per step (like DDIM), high
order (like implicit Adams), robust to noise-estimation error (the ERS
strategy).

Structure of one step i (i >= k-1; the first k-1 steps are DDIM warmup while
the Lagrange buffer fills):

  1. select bases  tau_{1..k}  via ERS (Eq. 16/17) using delta_eps
  2. predict       eps_bar_{i+1} = L_eps(t_{i+1})            (Eq. 13/14)
  3. correct       eps_ti = (9 eps_bar_{i+1} + 19 eps_i - 5 eps_{i-1}
                             + eps_{i-2}) / 24               (Eq. 11)
  4. x-update      x_{i+1} = DDIM(x_i, eps_ti)               (Eq. 8)
  5. observe       eps_{i+1} = eps_theta(x_{i+1}, t_{i+1})   (1 NFE)
  6. measure       delta_eps = || eps_{i+1} - eps_bar_{i+1} ||_2   (Eq. 15)

The final iteration skips step 5/6 (the sample is finished), so a run with N
steps costs exactly N NFE (1 initial eval + N-1 in-loop evals).

Engine notes (serving path):

* The loop is a single ``jax.lax.scan`` over the step grid, so one jit
  compile covers a whole (sample-shape, nfe, k) bucket and XLA can reuse the
  Lagrange buffers in place.
* :func:`sample_scan` takes the eps/t buffers as explicit arguments so a
  jitting caller (``repro.serving.BatchedSampler``) can donate them.
* Steps 2-4 default to the fused Pallas kernel
  (``repro.kernels.era_update``) — one HBM round trip per operand instead of
  ~(k+5) — with automatic ``interpret=True`` fallback off-TPU and a
  pure-jnp fallback if Pallas itself is unavailable.
* :func:`sample_scan` optionally takes explicit carry ``shardings``
  (``parallel.sharding.sampler_shardings``): latents and Lagrange buffers
  batch-sharded over a mesh's data axes, t grid replicated.  With
  ``per_sample=True`` every step's ERS math is row-local, so the sharded
  scan runs with **zero cross-device collectives inside the loop** (the only
  batch reduction, the delta_eps diagnostic mean, happens once after it).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import lagrange
from repro.core.program import (
    SolverProgram,
    StepMask,
    step_active,
    step_row_times,
)
from repro.core.schedules import NoiseSchedule, timesteps
from repro.core.solver_base import (
    EpsFn,
    SolverConfig,
    SolverOutput,
    buffer_append,
    buffer_init,
    ddim_step,
    step_grid,
)

Array = jax.Array

# Adams--Moulton order-4 corrector coefficients (paper Eq. 10/11).
AM4 = (9.0 / 24.0, 19.0 / 24.0, -5.0 / 24.0, 1.0 / 24.0)


@dataclasses.dataclass(frozen=True)
class ERAConfig(SolverConfig):
    """ERA-Solver options (defaults follow the paper's main setting)."""

    k: int = 4                     # Lagrange interpolation order
    lam: float = 5.0               # power-scale hyperparameter (Eq. 17)
    selection: str = "ers"         # "ers" | "fixed" | "const"
    const_power: float = 1.0       # used when selection == "const"
    error_norm: str = "global"     # "global" (Eq. 15) | "mean" (per-sample mean)
    use_fused_update: bool = True  # route step 2-4 through the Pallas kernel
    # beyond-paper: independent delta_eps + base selection per batch element
    # (the paper shares one scalar across the batch)
    per_sample: bool = False


_FUSED_OK: dict[str, bool] = {}
_FUSED_TOL = 1e-5


def _fused_ops():
    """The Pallas wrapper module, or None when the fused path is unusable.

    Unusable means Pallas missing OR the kernel failing the one-time (per
    process, per backend) numerics parity probe against the pure-jnp
    reference — every ERA entry point shares this gate, so a misbehaving
    kernel degrades to the jnp combine instead of silently wrong samples.

    The probe can only execute eagerly (it runs the kernel and reads the
    error as a Python float).  If the gate's first consultation happens
    inside an outer jit trace — a jitting caller's very first trace on a
    fresh process — the probe is deferred rather than run-and-failed: that
    trace takes the jnp path, the cache stays unpoisoned, and the next
    eager consultation (e.g. ``serving.BatchedSampler``, which checks the
    gate before building each jitted bucket) enables the kernel normally.
    Caveat for direct jitting callers: jax never retraces a cached shape,
    so an executable compiled during the deferral keeps the jnp path for
    its lifetime even after ``fused_path_ok()`` turns True — consult the
    gate eagerly before jitting (as the engine does) to avoid that.
    """
    try:
        from repro.kernels import ops as _kops
    except Exception:  # missing pallas / unsupported backend
        return None
    backend = jax.default_backend()
    if backend not in _FUSED_OK:
        if not jax.core.trace_state_clean():
            return None  # mid-trace: defer the probe, don't cache a verdict
        try:
            _FUSED_OK[backend] = _kops.fused_step_parity() <= _FUSED_TOL
        except Exception:
            _FUSED_OK[backend] = False
    return _kops if _FUSED_OK[backend] else None


def _seq_sq_sums(d: Array, valid: Array | None) -> Array:
    """Per-row sum of squared entries, accumulated position-by-position.

    The mixed-seq-len serving path right-pads samples from length L to a
    seq bucket L' and must leave every valid row's delta_eps — and hence
    its ERS Lagrange-basis selection — **bit-identical** to the exact-shape
    run.  A plain ``jnp.sum`` over the padded layout cannot promise that:
    XLA may re-associate a size-L' reduction differently from a size-L one
    even when the extra entries are exact zeros.  So the reduction here is
    (a) features first, at fixed per-position shape, then (b) a strictly
    sequential ``lax.scan`` over positions — appending zero-masked pad
    positions only appends ``acc + 0.0`` steps, which are exact no-ops.
    The accumulation is elementwise per row, so a batch-sharded run stays
    collective-free.  Rank-2 inputs (no sequence axis) keep the plain
    squared norm.
    """
    d = d.astype(jnp.float32)
    if d.ndim < 3:
        return jnp.sum(d.reshape(d.shape[0], -1) ** 2, axis=-1)
    p = jnp.sum(d.reshape(d.shape[0], d.shape[1], -1) ** 2, axis=-1)  # (B, S)
    if valid is not None:
        p = jnp.where(valid, p, 0.0)
    total, _ = jax.lax.scan(
        lambda acc, ps: (acc + ps, None),
        jnp.zeros(d.shape[0], jnp.float32),
        p.T,
    )
    return total


def _delta_eps(
    e_obs: Array, e_pred: Array, mode: str, valid: Array | None = None
) -> Array:
    if mode == "global":
        d = (e_obs - e_pred).astype(jnp.float32)
        if valid is None:
            return jnp.linalg.norm(d.reshape(-1))
        # masked Eq. 15: pad positions contribute exactly zero
        return jnp.sqrt(jnp.sum(_seq_sq_sums(d, valid)))
    if mode == "mean":  # per-sample L2, averaged — batch-size invariant
        return jnp.mean(_delta_eps_batch(e_obs, e_pred, valid))
    raise ValueError(f"unknown error_norm {mode!r}")


def _delta_eps_batch(
    e_obs: Array, e_pred: Array, valid: Array | None = None
) -> Array:
    """Per-sample L2 errors, (B,), reduced only over valid positions."""
    return jnp.sqrt(_seq_sq_sums(e_obs - e_pred, valid))


def era_combine(
    eps_sel: Array,      # (k, *x) selected buffer noises
    t_sel: Array,        # (k,) their times
    e_hist: Array,       # (3, *x) eps at steps i, i-1, i-2
    t_next: Array,
) -> tuple[Array, Array]:
    """Predictor + corrector combine: returns (eps_bar_next, eps_corr).

    Kept as a standalone function so the Pallas fused kernel
    (repro.kernels.era_update) can be validated against it and swapped in.
    """
    eps_bar = lagrange.interpolate(eps_sel, t_sel, t_next)
    c0, c1, c2, c3 = AM4
    eps_corr = c0 * eps_bar + c1 * e_hist[0] + c2 * e_hist[1] + c3 * e_hist[2]
    return eps_bar, eps_corr


def alloc_buffers(
    x: Array, config: ERAConfig, shardings=None
) -> tuple[Array, Array]:
    """Fresh Lagrange eps/t buffers sized for ``config.nfe`` steps.

    Callers that jit :func:`sample_scan` can allocate these outside the
    compiled function and donate them (``donate_argnums``) — the scan then
    updates them in place for the whole sampling run.

    With ``shardings`` (see :func:`sample_scan`), the eps buffer — the
    largest array in a sampling run — is created batch-sharded in place
    rather than materialized on one device and redistributed.
    """
    return buffer_init(x, config.nfe + 1, config.solver_dtype, shardings)


def sample(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: ERAConfig,
) -> SolverOutput:
    """Self-contained entry: allocates buffers, then runs the scan loop."""
    eps_buf, t_buf = alloc_buffers(x_init, config)
    return sample_scan(eps_fn, x_init, eps_buf, t_buf, schedule, config)


def sample_scan(
    eps_fn: EpsFn,
    x_init: Array,
    eps_buf: Array,      # (nfe+1, *x.shape) zeros, donatable
    t_buf: Array,        # (nfe+1,) zeros, donatable
    schedule: NoiseSchedule,
    config: ERAConfig,
    shardings=None,      # optional carry placement, duck-typed with fields
                         # .x/.eps_buf/.t_buf/.delta_eps (NamedShardings) —
                         # see parallel.sharding.sampler_shardings
    lengths: Array | None = None,  # (B,) valid seq lengths of a right-
                                   # padded mixed-seq-len batch; masks the
                                   # ERS error norms so pad positions can
                                   # never flip a basis selection
    steps: StepMask | None = None,  # mixed-NFE channel: per-row step
                                    # counts + per-row time grids; a row's
                                    # carry freezes bitwise once spent
) -> SolverOutput:
    n = config.nfe
    k = config.k
    if n < k:
        raise ValueError(f"ERA-Solver needs nfe >= k ({n} < {k})")
    if steps is not None and not config.per_sample:
        raise ValueError(
            "mixed-NFE step masking needs per-sample ERS (per_sample=True):"
            " a shared delta_eps would couple rows with different horizons"
        )
    if lengths is not None and x_init.ndim < 3:
        raise ValueError(
            "lengths masking needs batch-of-sequences latents (B, S, ...); "
            f"got x of rank {x_init.ndim}"
        )
    if eps_buf.shape != (n + 1,) + x_init.shape:
        raise ValueError(
            f"eps buffer shape {eps_buf.shape} != {(n + 1,) + x_init.shape}"
        )
    if t_buf.shape != (n + 1,):
        raise ValueError(f"t buffer shape {t_buf.shape} != {(n + 1,)}")
    if steps is None:
        ts = timesteps(schedule, n, config.scheme, t_end=config.t_end)
        t0 = ts[0]
    else:
        # each row starts on its own grid; the shared t_buf goes unused
        # under step masking (Lagrange node times gather from steps.ts,
        # which holds exactly the floats an exact run appends to t_buf)
        ts = None
        t0 = steps.ts[:, 0].reshape((-1,) + (1,) * (x_init.ndim - 1))
    dt = config.solver_dtype
    kops = _fused_ops() if config.use_fused_update else None
    am4 = jnp.asarray(AM4, jnp.float32)
    valid = (
        None
        if lengths is None
        else jnp.arange(x_init.shape[1], dtype=jnp.int32) < lengths[:, None]
    )  # (B, S) position-validity mask for the error norms

    x = x_init.astype(dt)
    if shardings is not None:
        x = jax.lax.with_sharding_constraint(x, shardings.x)
        eps_buf = jax.lax.with_sharding_constraint(eps_buf, shardings.eps_buf)
        t_buf = jax.lax.with_sharding_constraint(t_buf, shardings.t_buf)
    # Alg. 1 line 2/3: delta_eps initialized to lambda (power = 1, uniform
    # selection); initial observation appended at index 0.
    e0 = eps_fn(x, t0).astype(dt)
    eps_buf, t_buf = buffer_append(
        eps_buf, t_buf, jnp.int32(0), e0,
        jnp.float32(0.0) if steps is not None else ts[0],
    )
    delta_eps = (
        jnp.full((x.shape[0],), config.lam, jnp.float32)
        if config.per_sample
        else jnp.float32(config.lam)
    )
    if shardings is not None:
        delta_eps = jax.lax.with_sharding_constraint(
            delta_eps, shardings.delta_eps
        )

    # ERS selections are emitted per step (warmup steps emit the zero
    # placeholder) so callers can assert two runs selected identical bases
    tau_shape = (x.shape[0], k) if config.per_sample else (k,)

    def warm_branch(ops):
        x, eps_buf, t_buf, de, i, t_cur, t_next = ops
        e_cur = jax.lax.dynamic_index_in_dim(eps_buf, i, 0, keepdims=False)
        x_next = ddim_step(schedule, x, e_cur, t_cur, t_next)
        # prediction placeholder: the DDIM-held noise; no selection yet
        return x_next, e_cur, jnp.zeros(tau_shape, jnp.int32)

    def main_branch(ops):
        x, eps_buf, t_buf, de, i, t_cur, t_next = ops
        e_hist = jnp.stack(
            [
                jax.lax.dynamic_index_in_dim(eps_buf, i - j, 0, keepdims=False)
                for j in range(3)
            ]
        )
        if config.per_sample:
            # beyond-paper: each batch element selects its own bases from
            # its own measured error
            tau = jax.vmap(
                lambda d: lagrange.select_bases(
                    i, k, d, config.lam, config.selection, config.const_power
                )
            )(de)                                            # (B, k)
            if steps is None:
                t_sel = jnp.take(t_buf, tau, axis=0)         # (B, k)
            else:
                # per-row grids: node times come from the row's own grid
                # (identical floats to the exact run's t_buf entries)
                t_sel = jax.vmap(
                    lambda ts_r, tau_r: jnp.take(ts_r, tau_r, axis=0)
                )(steps.ts, tau)                             # (B, k)
            # per-sample gather from the (cap, B, ...) buffer
            eps_sel = jax.vmap(
                lambda tau_b, buf_b: jnp.take(buf_b, tau_b, axis=0),
                in_axes=(0, 1),
                out_axes=0,
            )(tau, eps_buf)                                  # (B, k, ...)
            e_hist_b = jnp.moveaxis(e_hist, 1, 0)            # (B, 3, ...)
            cx, ce = schedule.ddim_coeffs(t_cur, t_next)
            if kops is not None:
                # fused per-sample step: vmap the Pallas kernel over the
                # batch (each element carries its own Lagrange nodes; with
                # per-row grids, also its own times and DDIM coefficients)
                if steps is None:
                    x_next, eps_bar = jax.vmap(
                        lambda xb, es, tn, eh: kops.era_step(
                            xb, es, tn, eh, t_next, cx, ce, am4
                        )
                    )(x, eps_sel, t_sel, e_hist_b)
                else:
                    x_next, eps_bar = jax.vmap(
                        lambda xb, es, tn, eh, tnb, cxb, ceb: kops.era_step(
                            xb, es, tn, eh, tnb, cxb, ceb, am4
                        )
                    )(
                        x, eps_sel, t_sel, e_hist_b,
                        t_next.reshape(-1), cx.reshape(-1), ce.reshape(-1),
                    )
                return x_next, eps_bar, tau
            if steps is None:
                eps_bar, eps_corr = jax.vmap(
                    era_combine, in_axes=(0, 0, 0, None)
                )(eps_sel, t_sel, e_hist_b, t_next)
            else:
                eps_bar, eps_corr = jax.vmap(era_combine)(
                    eps_sel, t_sel, e_hist_b, t_next.reshape(-1)
                )
            x_next = ddim_step(schedule, x, eps_corr, t_cur, t_next)
            return x_next, eps_bar, tau
        tau = lagrange.select_bases(
            i, k, de, config.lam, config.selection, config.const_power
        )
        t_sel = jnp.take(t_buf, tau, axis=0)
        eps_sel = jnp.take(eps_buf, tau, axis=0)
        if kops is not None:
            # fused step: predictor combine + AM4 corrector + DDIM x-update
            # in one HBM pass
            cx, ce = schedule.ddim_coeffs(t_cur, t_next)
            x_next, eps_bar = kops.era_step(
                x, eps_sel, t_sel, e_hist, t_next, cx, ce, am4
            )
            return x_next, eps_bar, tau
        eps_bar, eps_corr = era_combine(eps_sel, t_sel, e_hist, t_next)
        x_next = ddim_step(schedule, x, eps_corr, t_cur, t_next)
        return x_next, eps_bar, tau

    def step(carry, inp):
        x, eps_buf, t_buf, de = carry
        if steps is None:
            i, t_cur, t_next = inp
        else:
            i = inp
            t_cur, t_next = step_row_times(steps, i, x.ndim)
        ops = (x, eps_buf, t_buf, de, i, t_cur, t_next)
        x_next, eps_bar, tau = jax.lax.cond(
            i < k - 1, warm_branch, main_branch, ops
        )
        if steps is not None:
            # a spent row's latents freeze bitwise for the rest of the scan
            x_next = jnp.where(step_active(steps, i, x.ndim), x_next, x)

        # Observe eps at the new point — except on the final step, whose
        # x_next is the output (keeps total cost at exactly `nfe` evals).
        # Under step masking the skip becomes per-row: each row's last
        # *own* step appends zeros and keeps its delta_eps, exactly like
        # the exact-shape run's final step (the whole-batch cond still
        # spares the bucket's terminal eval).
        def observe(_):
            e_new = eps_fn(x_next, t_next).astype(dt)
            if config.per_sample:
                de_new = _delta_eps_batch(e_new, eps_bar, valid)
            else:
                de_new = _delta_eps(e_new, eps_bar, config.error_norm, valid)
            if steps is not None:
                obs = (i + 1) < steps.active_steps           # (B,)
                e_new = jnp.where(
                    obs.reshape((-1,) + (1,) * (e_new.ndim - 1)), e_new, 0.0
                )
                de_new = jnp.where(obs, de_new, de)
            return e_new, de_new

        def skip(_):
            return jnp.zeros_like(x_next), de

        e_new, de_new = jax.lax.cond(i + 1 < n, observe, skip, None)
        # Alg. 1 line 16: delta_eps only updates once predictions are real.
        de = jnp.where(i >= k - 1, de_new, de)
        eps_buf, t_buf = buffer_append(
            eps_buf, t_buf, i + 1, e_new,
            jnp.float32(0.0) if steps is not None else t_next,
        )
        traj_x = x_next if config.return_trajectory else None
        # per-sample: emit the raw (B,) errors and reduce after the scan, so
        # a batch-sharded run keeps the loop body free of collectives
        return (x_next, eps_buf, t_buf, de), (de, tau, traj_x)

    grid = (
        step_grid(ts) if steps is None else jnp.arange(n, dtype=jnp.int32)
    )
    (x, eps_buf, t_buf, delta_eps), (de_hist, tau_hist, traj_tail) = (
        jax.lax.scan(step, (x, eps_buf, t_buf, delta_eps), grid)
    )
    aux: dict[str, Any] = {}
    if config.per_sample:
        aux["delta_eps_history_per_sample"] = de_hist        # (nfe, B)
        aux["delta_eps_history"] = jnp.mean(de_hist, axis=-1)
        # per-row selected Lagrange bases per step — the engine's padding-
        # invariance wall asserts these match the exact-shape run exactly
        aux["ers_selection_history"] = tau_hist              # (nfe, B, k)
    else:
        aux["delta_eps_history"] = de_hist
    if config.return_trajectory:
        aux["trajectory"] = jnp.concatenate(
            [x_init.astype(dt)[None], traj_tail], axis=0
        )
    return SolverOutput(x0=x.astype(x_init.dtype), nfe=jnp.int32(n), aux=aux)


class ERAProgram(SolverProgram):
    """ERA-Solver as a serving program.

    The paper-default config shares one scalar delta_eps across the batch —
    every row couples through that global error norm, so such configs are
    not fusable (strangers or pad rows would change each request's result).
    The engine default turns on per-sample ERS, which makes a batch-of-N
    run equivalent to N independent runs and the program fully fusable."""

    name = "era"
    config_cls = ERAConfig
    aux_row_axes = {
        "trajectory": 1,
        "delta_eps_history_per_sample": 1,
        "ers_selection_history": 1,
    }
    aux_step_axes = {
        "trajectory": 0,
        "delta_eps_history": 0,
        "delta_eps_history_per_sample": 0,
        "ers_selection_history": 0,
    }

    def engine_config(self) -> ERAConfig:
        # per-sample ERS isolates co-batched requests from each other
        return ERAConfig(per_sample=True)

    def fusable(self, cfg: ERAConfig) -> bool:
        return cfg.per_sample

    def per_sample_state(self, cfg: ERAConfig) -> bool:
        return cfg.per_sample

    def supports_lengths(self, cfg: ERAConfig) -> bool:
        """ERA's only cross-position math is the ERS error norm, which
        ``sample_scan`` masks (position-sequential accumulation, so padded
        and exact-shape runs agree bitwise); everything else — Lagrange
        predictor, AM4 corrector, DDIM update — is elementwise."""
        return True

    def supports_steps(self, cfg: ERAConfig) -> bool:
        """Mixed-NFE step masking needs per-sample ERS: each row carries
        its own delta_eps and basis selections, so freezing a spent row
        can never perturb a live one (a shared scalar delta_eps would
        couple rows with different horizons)."""
        return cfg.per_sample

    def validate(self, req, cfg: ERAConfig, dp: int = 1) -> None:
        super().validate(req, cfg, dp=dp)
        if req.nfe < cfg.k:
            raise ValueError(
                f"ERA-Solver needs nfe >= k ({req.nfe} < {cfg.k}); "
                "lower k in the engine's solver_config or raise nfe"
            )

    def num_buffers(self, cfg: ERAConfig) -> int:
        return 2

    def alloc_buffers(self, x_like, cfg: ERAConfig, shardings=None):
        return alloc_buffers(x_like, cfg, shardings)

    def pre_compile(self, cfg: ERAConfig) -> None:
        # consult the fused-kernel parity gate eagerly — the probe cannot
        # run inside a jit trace, and a process serving only compiled
        # buckets would otherwise never enable the Pallas step
        if cfg.use_fused_update:
            _fused_ops()

    def sample_scan(
        self, eps_fn, x_init, buffers, schedule, cfg, shardings=None,
        lengths=None, steps=None,
    ):
        eps_buf, t_buf = buffers
        return sample_scan(
            eps_fn, x_init, eps_buf, t_buf, schedule, cfg,
            shardings=shardings, lengths=lengths, steps=steps,
        )

    def scope_aux(
        self,
        aux: dict,
        off: int,
        batch: int,
        seq_len: int | None = None,
        n_steps: int | None = None,
        padded_steps: int | None = None,
    ) -> dict:
        scoped = super().scope_aux(
            aux, off, batch, seq_len=seq_len,
            n_steps=n_steps, padded_steps=padded_steps,
        )
        if scoped is not aux and "delta_eps_history_per_sample" in scoped:
            # the batch-mean diagnostic must cover only this request's rows
            # (pad rows would dilute it; batch-mates would leak into it)
            scoped["delta_eps_history"] = jnp.mean(
                scoped["delta_eps_history_per_sample"], axis=-1
            )
        return scoped
