"""DDIM sampler (Song et al. 2020a) — the order-1 diffusion-ODE baseline.

Deterministic (eta = 0) DDIM is exactly Euler on the diffusion ODE in the
(alpha, sigma)-parameterization; the paper's Eq. 8.  1 NFE per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.schedules import NoiseSchedule, timesteps
from repro.core.solver_base import (
    EpsFn,
    SolverConfig,
    SolverOutput,
    ddim_step,
    trajectory_append,
    trajectory_init,
)


def sample(
    eps_fn: EpsFn,
    x_init: jax.Array,
    schedule: NoiseSchedule,
    config: SolverConfig,
) -> SolverOutput:
    n = config.nfe
    ts = timesteps(schedule, n, config.scheme, t_end=config.t_end)
    traj = trajectory_init(x_init, n, config.return_trajectory)

    def body(i, carry):
        x, traj = carry
        t_cur, t_next = ts[i], ts[i + 1]
        eps = eps_fn(x, t_cur)
        x = ddim_step(schedule, x, eps, t_cur, t_next)
        traj = trajectory_append(traj, i + 1, x)
        return (x, traj)

    x, traj = jax.lax.fori_loop(0, n, body, (x_init, traj))
    aux = {"trajectory": traj} if traj is not None else {}
    return SolverOutput(x0=x, nfe=jnp.int32(n), aux=aux)
