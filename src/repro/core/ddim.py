"""DDIM sampler (Song et al. 2020a) — the order-1 diffusion-ODE baseline.

Deterministic (eta = 0) DDIM is exactly Euler on the diffusion ODE in the
(alpha, sigma)-parameterization; the paper's Eq. 8.  1 NFE per step.

Engine notes: the loop is a single ``jax.lax.scan`` over the step grid
(:class:`DDIMProgram`), so one jit compile covers a whole (sample-shape,
nfe) bucket and the serving engine can batch-shard the carry over a mesh.
DDIM keeps no history, so the program has no donatable buffers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.program import (
    SolverProgram,
    StepMask,
    constrain_x,
    step_active,
    step_row_times,
    trajectory_aux,
)
from repro.core.schedules import NoiseSchedule, timesteps
from repro.core.solver_base import (
    EpsFn,
    SolverConfig,
    SolverOutput,
    ddim_step,
    step_grid,
)


def sample_scan(
    eps_fn: EpsFn,
    x_init: jax.Array,
    schedule: NoiseSchedule,
    config: SolverConfig,
    shardings=None,
    steps: StepMask | None = None,
) -> SolverOutput:
    n = config.nfe
    x = constrain_x(x_init, shardings)

    def step(carry, inp):
        x = carry
        if steps is None:
            _i, t_cur, t_next = inp
        else:
            # mixed-NFE batch: each row reads its own grid, and a row
            # whose steps are spent keeps its latents bitwise unchanged
            t_cur, t_next = step_row_times(steps, inp, x.ndim)
        eps = eps_fn(x, t_cur)
        x_next = ddim_step(schedule, x, eps, t_cur, t_next)
        if steps is not None:
            x_next = jnp.where(step_active(steps, inp, x.ndim), x_next, x)
        return x_next, (x_next if config.return_trajectory else None)

    if steps is None:
        grid = step_grid(timesteps(schedule, n, config.scheme, t_end=config.t_end))
    else:
        grid = jnp.arange(n, dtype=jnp.int32)
    x, traj_tail = jax.lax.scan(step, x, grid)
    aux = trajectory_aux(x_init, traj_tail, config.return_trajectory)
    return SolverOutput(x0=x, nfe=jnp.int32(n), aux=aux)


def sample(
    eps_fn: EpsFn,
    x_init: jax.Array,
    schedule: NoiseSchedule,
    config: SolverConfig,
) -> SolverOutput:
    return sample_scan(eps_fn, x_init, schedule, config)


class DDIMProgram(SolverProgram):
    name = "ddim"

    def supports_steps(self, cfg):
        return True

    def sample_scan(
        self, eps_fn, x_init, buffers, schedule, cfg, shardings=None,
        lengths=None, steps=None,
    ):
        # DDIM's update is elementwise over positions, so a right-padded
        # batch needs no solver-side masking (`lengths` is the denoiser's
        # concern); accepted for the uniform program surface.
        assert not buffers
        return sample_scan(
            eps_fn, x_init, schedule, cfg, shardings=shardings, steps=steps
        )
