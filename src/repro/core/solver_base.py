"""Common solver machinery.

A *solver* turns a pretrained noise-prediction network ``eps_fn(x, t) -> eps``
(t a scalar, broadcast over the batch) plus a :class:`NoiseSchedule` and a
timestep grid into a sampling loop.  Every solver here is a pure function of
its inputs and is jit/pjit-compatible: buffers are fixed-size, control flow is
``lax.scan`` / ``lax.fori_loop`` / ``lax.cond``, and nothing syncs to the
host.  Fixed-capacity buffers are allocated up front (:func:`buffer_init`)
so a jitting caller can donate them and the whole run compiles once per
(sample-shape, nfe) bucket.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.schedules import NoiseSchedule

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]


class SolverOutput(NamedTuple):
    """Result of a sampling run."""

    x0: Array                 # final sample (at t_N)
    nfe: Array                # number of network evaluations actually used
    aux: dict[str, Any]       # solver-specific diagnostics


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Options shared by all solvers."""

    nfe: int = 10                    # network-evaluation budget
    scheme: str = "uniform"          # timestep scheme
    t_end: float | None = None       # override schedule.t_end
    solver_dtype: Any = jnp.float32  # dtype for solver state / buffer math
    return_trajectory: bool = False  # record x at every step (debug/bench)


def ddim_step(
    schedule: NoiseSchedule, x: Array, eps: Array, t_cur: Array, t_next: Array
) -> Array:
    """Diffusion-ODE / deterministic DDIM update (paper Eq. 8).

    Computed in x's dtype (the solver state dtype) — f32 coefficients must
    not silently promote a bf16 solver state."""
    cx, ce = schedule.ddim_coeffs(t_cur, t_next)
    return cx.astype(x.dtype) * x + ce.astype(x.dtype) * eps.astype(x.dtype)


def buffer_init(
    x_like: Array, capacity: int, dtype, shardings=None
) -> tuple[Array, Array]:
    """Fixed-capacity noise/time buffers (the paper's Lagrange buffer Omega).

    TPU adaptation: Algorithm 1 appends to a Python list; we preallocate
    ``capacity`` slots and append via ``dynamic_update_index_in_dim`` so the
    whole sampling loop stays inside a single XLA program.

    With ``shardings`` (duck-typed ``.eps_buf``/``.t_buf`` NamedShardings),
    the eps buffer — the largest array in a sampling run — is created
    batch-sharded in place rather than materialized on one device and
    redistributed.
    """
    if shardings is None:
        eps_buf = jnp.zeros((capacity,) + x_like.shape, dtype)
        t_buf = jnp.zeros((capacity,), jnp.float32)
        return eps_buf, t_buf
    eps_buf = jnp.zeros(
        (capacity,) + x_like.shape, dtype, device=shardings.eps_buf
    )
    t_buf = jnp.zeros((capacity,), jnp.float32, device=shardings.t_buf)
    return eps_buf, t_buf


def buffer_append(
    eps_buf: Array, t_buf: Array, idx: Array, eps: Array, t: Array
) -> tuple[Array, Array]:
    eps_buf = jax.lax.dynamic_update_index_in_dim(
        eps_buf, eps.astype(eps_buf.dtype), idx, axis=0
    )
    t_buf = jax.lax.dynamic_update_index_in_dim(
        t_buf, jnp.asarray(t, t_buf.dtype), idx, axis=0
    )
    return eps_buf, t_buf


def step_grid(ts: Array) -> tuple[Array, Array, Array]:
    """Scan inputs for an n-step loop over the (n+1,) time grid ``ts``.

    Returns ``(i, t_cur, t_next)`` arrays of length n — the per-step xs for
    a ``lax.scan`` solver loop (one compile covers the whole grid; the carry
    reuses the solver buffers in place).
    """
    n = ts.shape[0] - 1
    return jnp.arange(n, dtype=jnp.int32), ts[:-1], ts[1:]


def trajectory_init(x: Array, num_steps: int, enabled: bool) -> Array | None:
    if not enabled:
        return None
    traj = jnp.zeros((num_steps + 1,) + x.shape, x.dtype)
    return traj.at[0].set(x)


def trajectory_append(traj: Array | None, i: Array, x: Array) -> Array | None:
    if traj is None:
        return None
    return jax.lax.dynamic_update_index_in_dim(traj, x, i, axis=0)
