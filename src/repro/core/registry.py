"""Solver registry — the framework's public sampling API.

    from repro.core import get_solver, SolverConfig
    out = get_solver("era")(eps_fn, x_T, schedule, ERAConfig(nfe=10, k=4))
"""

from __future__ import annotations

import functools
from typing import Callable

from repro.core import adams, ddim, dpm_solver, era
from repro.core.era import ERAConfig
from repro.core.solver_base import SolverConfig, SolverOutput

SampleFn = Callable[..., SolverOutput]

_SOLVERS: dict[str, SampleFn] = {
    # baselines the paper compares against
    "ddim": ddim.sample,
    "explicit_adams": adams.explicit_adams_sample,          # PNDM/FON family
    "implicit_adams_pece": adams.implicit_adams_pece_sample,
    "dpm_solver_2": functools.partial(dpm_solver.sample, order=2, fast=False),
    "dpm_solver_fast": functools.partial(dpm_solver.sample, order=3, fast=True),
    "dpm_solver_pp2m": dpm_solver.sample_pp2m,
    # the paper's contribution (+ its Table-4 "fixed" ablation)
    "era": era.sample,
}


def get_solver(name: str) -> SampleFn:
    try:
        return _SOLVERS[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; available: {sorted(_SOLVERS)}"
        ) from None


def solver_names() -> list[str]:
    return sorted(_SOLVERS)


def default_config(name: str, **kw) -> SolverConfig:
    if name == "era":
        return ERAConfig(**kw)
    return SolverConfig(**kw)
