"""Solver registry — the framework's public sampling API.

Every solver is registered as a :class:`~repro.core.program.SolverProgram`
(the uniform compiled-sampling contract: scan entry + donatable buffers +
carry pspecs + request policy + default configs), so the serving engine can
fuse and route requests to any of them.  The classic functional surface is
kept on top:

    from repro.core import get_solver, SolverConfig
    out = get_solver("era")(eps_fn, x_T, schedule, ERAConfig(nfe=10, k=4))

    from repro.core import get_program
    program = get_program("ddim")          # the serving-engine surface
"""

from __future__ import annotations

from typing import Callable

from repro.core import adams, ddim, dpm_adaptive, dpm_solver, era
from repro.core.program import SolverProgram
from repro.core.solver_base import SolverConfig, SolverOutput

SampleFn = Callable[..., SolverOutput]

_PROGRAMS: dict[str, SolverProgram] = {
    # baselines the paper compares against
    "ddim": ddim.DDIMProgram(),
    "explicit_adams": adams.ExplicitAdamsProgram(),         # PNDM/FON family
    "implicit_adams_pece": adams.ImplicitAdamsPECEProgram(),
    "dpm_solver_2": dpm_solver.DPMSolverProgram(
        "dpm_solver_2", order=2, fast=False
    ),
    "dpm_solver_fast": dpm_solver.DPMSolverProgram(
        "dpm_solver_fast", order=3, fast=True
    ),
    "dpm_solver_pp2m": dpm_solver.DPMpp2MProgram(),
    "dpm_adaptive": dpm_adaptive.AdaptiveDPMProgram(),
    # the paper's contribution (+ its Table-4 "fixed" ablation)
    "era": era.ERAProgram(),
}


def get_program(name: str) -> SolverProgram:
    try:
        return _PROGRAMS[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; available: {sorted(_PROGRAMS)}"
        ) from None


def get_solver(name: str) -> SampleFn:
    """The classic functional entry: ``f(eps_fn, x_T, schedule, cfg)``."""
    return get_program(name).sample


def solver_names() -> list[str]:
    return sorted(_PROGRAMS)


def default_config(name: str, **kw) -> SolverConfig:
    return get_program(name).default_config(**kw)
