"""Adaptive DPM-Solver — PID-controlled accept/reject stepping under jit.

The fixed-grid solvers spend their NFE budget on a schedule chosen ahead
of time; this program instead *adapts* its step size to the local
truncation error, the DPM-Solver-12 scheme of Lu et al. 2022a (Sec. 3.3)
with the PID step-size controller popularized by k-diffusion:

* each iteration advances in half-logSNR (lambda) space by a trial step
  ``h``, computing an embedded order-1/2 pair that shares the first eps
  evaluation — ``x_low`` (DPM-Solver-1) and ``x_high`` (DPM-Solver-2,
  midpoint) — for 2 NFE per iteration;
* the pairwise difference is normalized by ``delta = max(atol, rtol *
  max(|x_low|, |x_prev|))`` and reduced to a per-row RMS error;
* a PID controller turns the error into a step-size factor (limited by
  ``1 + atan(f - 1)``) and an accept/reject decision (``factor >=
  accept_safety``); rejected steps retry from the same state with the
  shrunken ``h``.

Serving adaptation: everything above runs as a **fixed-shape**
``lax.scan`` with per-row early exit, so the program jit-compiles once per
(sample-shape, nfe-bucket) like every other registry solver.  ``cfg.nfe``
is the per-request NFE *budget*: the scan runs ``nfe // 2`` iterations and
a row that converges earlier freezes bitwise (its remaining iterations are
identity).  The per-row NFE actually spent is reported as the
``realized_nfe`` aux (a ``(B,)`` int32), which the serving layer surfaces
in each request's ``info``.  Mixed-NFE batches work through the same
:class:`~repro.core.program.StepMask` channel as the fixed-grid solvers —
``active_steps`` caps each row's *iterations* (the grid times are ignored;
the controller chooses its own times).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.era import _seq_sq_sums
from repro.core.program import SolverProgram, StepMask, constrain_x
from repro.core.schedules import NoiseSchedule
from repro.core.solver_base import EpsFn, SolverConfig, SolverOutput

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdaptiveDPMConfig(SolverConfig):
    """Adaptive DPM-Solver options (defaults follow k-diffusion's
    ``sample_dpm_adaptive``).  ``nfe`` is the eval *budget* (2 per
    iteration), not a step count."""

    rtol: float = 0.05           # relative tolerance
    atol: float = 0.0078         # absolute tolerance
    h_init: float = 0.35         # first trial step in lambda space
    pcoeff: float = 0.0          # PID proportional coefficient
    icoeff: float = 1.0          # PID integral coefficient
    dcoeff: float = 0.0          # PID derivative coefficient
    accept_safety: float = 0.81  # accept iff limited factor >= this
    pid_eps: float = 1e-8        # guards 1/error
    order: int = 2               # embedded pair order (PID normalization)


def _row(v: Array, ndim: int) -> Array:
    """Reshape a (B,) vector to broadcast over (B,) + trailing dims."""
    return v.reshape(v.shape + (1,) * (ndim - 1))


def sample_adaptive_scan(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: AdaptiveDPMConfig,
    shardings=None,
    lengths: Array | None = None,
    steps: StepMask | None = None,
) -> SolverOutput:
    """The adaptive sampling loop as one fixed-shape XLA program.

    Rows step independently: each keeps its own lambda position, trial
    step size, PID error history, and done flag, so a batch mixes rows at
    different points of their integration without any cross-row coupling.
    """
    n_iters = max(config.nfe // 2, 1)
    dt = config.solver_dtype
    b1 = (config.pcoeff + config.icoeff + config.dcoeff) / config.order
    b2 = -(config.pcoeff + 2.0 * config.dcoeff) / config.order
    b3 = config.dcoeff / config.order

    t_begin = schedule.t_begin
    t_end = schedule.t_end if config.t_end is None else config.t_end
    # evaluate the lambda endpoints eagerly and pin them behind a barrier:
    # the accept/reject thresholding must see the same floats under jit and
    # eager (XLA's constant folder rounds transcendentals differently)
    with jax.ensure_compile_time_eval():
        lam0 = schedule.lam(jnp.float32(t_begin))
        lam_end = schedule.lam(jnp.float32(t_end))
    lam0 = jax.lax.optimization_barrier(lam0)
    lam_end = jax.lax.optimization_barrier(lam_end)

    x = constrain_x(x_init.astype(dt), shardings)
    batch = x.shape[0]
    ndim = x.ndim
    if lengths is not None and ndim >= 3:
        valid = jnp.arange(x.shape[1])[None, :] < lengths[:, None]
        feat = 1
        for d in x.shape[2:]:
            feat *= d
        numel = (lengths * feat).astype(jnp.float32)
    else:
        valid = None
        numel = jnp.full((batch,), float(x[0].size), jnp.float32)

    def body(carry, i):
        x, x_prev, lam, h, e2, e3, seeded, done, spent = carry
        cap = steps.active_steps if steps is not None else n_iters
        act = jnp.logical_and(~done, i < cap)            # (B,)
        actx = _row(act, ndim)

        lam_next = jnp.minimum(lam + h, lam_end)
        hh = lam_next - lam                              # (B,) actual step
        t = schedule.inv_lam(lam)
        t_next = schedule.inv_lam(lam_next)
        s_mid = schedule.inv_lam(lam + 0.5 * hh)
        tb, tnb, sb = _row(t, ndim), _row(t_next, ndim), _row(s_mid, ndim)
        hb = _row(hh, ndim)

        a_t = schedule.alpha(tb)
        a_n, s_n = schedule.alpha(tnb), schedule.sigma(tnb)
        a_s, s_s = schedule.alpha(sb), schedule.sigma(sb)

        e_t = eps_fn(x, tb).astype(dt)
        # DPM-Solver-1 (the low-order member shares e_t)
        x_low = (a_n / a_t).astype(dt) * x - (
            s_n * jnp.expm1(hb)
        ).astype(dt) * e_t
        # DPM-Solver-2, midpoint r1 = 1/2
        u = (a_s / a_t).astype(dt) * x - (
            s_s * jnp.expm1(0.5 * hb)
        ).astype(dt) * e_t
        e_s = eps_fn(u, sb).astype(dt)
        x_high = x_low - (s_n * jnp.expm1(hb)).astype(dt) * (e_s - e_t)

        delta = jnp.maximum(
            config.atol,
            config.rtol * jnp.maximum(jnp.abs(x_low), jnp.abs(x_prev)),
        )
        ratio = ((x_low - x_high) / delta).astype(jnp.float32)
        err = jnp.sqrt(_seq_sq_sums(ratio, valid) / numel)  # (B,) RMS
        inv_err = 1.0 / (err + config.pid_eps)

        e2_eff = jnp.where(seeded, e2, inv_err)
        e3_eff = jnp.where(seeded, e3, inv_err)
        factor = inv_err**b1 * e2_eff**b2 * e3_eff**b3
        factor = 1.0 + jnp.arctan(factor - 1.0)
        accept = factor >= config.accept_safety          # (B,)
        upd = jnp.logical_and(act, accept)
        updx = _row(upd, ndim)

        x_new = jnp.where(updx, x_high, x)
        x_prev_new = jnp.where(updx, x_low, x_prev)
        lam_new = jnp.where(upd, lam_next, lam)
        h_new = jnp.where(act, h * factor, h)
        e2_new = jnp.where(upd, inv_err, jnp.where(act, e2_eff, e2))
        e3_new = jnp.where(upd, e2_eff, jnp.where(act, e3_eff, e3))
        seeded_new = jnp.logical_or(seeded, act)
        done_new = jnp.logical_or(
            done, jnp.logical_and(upd, lam_next >= lam_end)
        )
        spent_new = spent + jnp.where(act, jnp.int32(2), jnp.int32(0))
        traj_x = x_new if config.return_trajectory else None
        return (
            x_new, x_prev_new, lam_new, h_new,
            e2_new, e3_new, seeded_new, done_new, spent_new,
        ), traj_x

    carry0 = (
        x,
        x,
        jnp.full((batch,), lam0, jnp.float32),
        jnp.full((batch,), config.h_init, jnp.float32),
        jnp.zeros((batch,), jnp.float32),
        jnp.zeros((batch,), jnp.float32),
        jnp.zeros((batch,), bool),
        jnp.zeros((batch,), bool),
        jnp.zeros((batch,), jnp.int32),
    )
    grid = jnp.arange(n_iters, dtype=jnp.int32)
    (x, _, _, _, _, _, _, _, spent), traj_tail = jax.lax.scan(
        body, carry0, grid
    )

    aux: dict = {"realized_nfe": spent}
    if config.return_trajectory and traj_tail is not None:
        aux["trajectory"] = jnp.concatenate(
            [x_init.astype(dt)[None], traj_tail], axis=0
        )
    return SolverOutput(
        x0=x.astype(x_init.dtype), nfe=jnp.max(spent), aux=aux
    )


def sample(
    eps_fn: EpsFn,
    x_init: Array,
    schedule: NoiseSchedule,
    config: AdaptiveDPMConfig,
) -> SolverOutput:
    return sample_adaptive_scan(eps_fn, x_init, schedule, config)


class AdaptiveDPMProgram(SolverProgram):
    name = "dpm_adaptive"
    config_cls = AdaptiveDPMConfig
    aux_row_axes = {"trajectory": 1, "realized_nfe": 0}
    aux_seq_axes = {"trajectory": 2}
    aux_step_axes = {"trajectory": 0}

    def per_sample_state(self, cfg):
        # lambda position / step size / PID history are all (B,)
        return True

    def supports_steps(self, cfg):
        return True

    def steps_for_nfe(self, nfe, cfg):
        # one adaptive iteration costs 2 NFE; active_steps caps iterations
        return max(nfe // 2, 1)

    def validate(self, req, cfg, dp=1):
        super().validate(req, cfg, dp=dp)
        if req.nfe < 2:
            raise ValueError(
                f"dpm_adaptive spends 2 NFE per accept/reject iteration, "
                f"so its budget must be >= 2; got nfe={req.nfe}"
            )
        if cfg.rtol <= 0.0 or cfg.atol <= 0.0:
            raise ValueError(
                f"dpm_adaptive tolerances must be positive, got "
                f"rtol={cfg.rtol}, atol={cfg.atol}"
            )
        if cfg.rtol < 1e-5 and cfg.atol < 1e-5:
            raise ValueError(
                f"dpm_adaptive tolerances rtol={cfg.rtol}, atol={cfg.atol} "
                f"are below the serveable floor (1e-5): the controller "
                f"cannot meet them within any finite NFE bucket, so the "
                f"request would always exhaust its budget unconverged"
            )
        if cfg.accept_safety >= 1.0 + jnp.pi / 2:
            raise ValueError(
                f"dpm_adaptive accept_safety={cfg.accept_safety} exceeds "
                f"the limiter ceiling 1 + pi/2: no step could ever be "
                f"accepted"
            )

    def sample_scan(
        self, eps_fn, x_init, buffers, schedule, cfg, shardings=None,
        lengths=None, steps=None,
    ):
        # the error RMS is masked per row via `lengths` (pad positions
        # contribute exact zeros through the sequential reduction), so
        # mixed-seq-len fusion cannot perturb a row's accept decisions
        assert not buffers
        return sample_adaptive_scan(
            eps_fn, x_init, schedule, cfg, shardings=shardings,
            lengths=lengths, steps=steps,
        )
