"""Noise schedules and timestep schemes for diffusion ODE solvers.

All solvers in :mod:`repro.core` operate on a continuous-time VP
(variance-preserving) diffusion, ``x_t = alpha(t) x_0 + sigma(t) eps`` with
``alpha(t)^2 + sigma(t)^2 = 1`` and ``t`` running from ``t_begin`` (~1, pure
noise) down to ``t_end`` (~0, data).  Discrete-time pretrained DDPMs (the
paper uses T=1000 linear-beta checkpoints from DDIM) are covered by the
closed-form continuous interpolation of the linear-beta schedule, which is
exact at the discrete grid points up to O(1/T^2).

The paper's timestep schemes:
  * ``uniform``  — t_i uniform in t (LSUN experiments, Sec. 4.1)
  * ``logsnr``   — t_i uniform in lambda(t) = log(alpha/sigma) (Cifar10,
                   following DPM-Solver)
  * ``quadratic``— t_i quadratic in t (common DDIM variant; extra)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class NoiseSchedule:
    """Continuous-time VP noise schedule.

    ``log_alpha_bar_fn`` maps t in [0, 1] to ``log(alpha_bar(t))`` =
    ``2 * log(alpha(t))``.  Everything else is derived.
    """

    name: str
    log_alpha_bar_fn: Callable[[Array], Array]
    t_begin: float = 1.0
    t_end: float = 1e-3
    # Discrete grid (for discrete-time pretrained model adapters).
    num_train_steps: int = 1000

    # -- primitives ---------------------------------------------------------
    def log_alpha_bar(self, t: Array) -> Array:
        return self.log_alpha_bar_fn(t)

    def alpha(self, t: Array) -> Array:
        """sqrt(alpha_bar(t)) — the signal coefficient."""
        return jnp.exp(0.5 * self.log_alpha_bar(t))

    def sigma(self, t: Array) -> Array:
        """sqrt(1 - alpha_bar(t)) — the noise coefficient."""
        return jnp.sqrt(-jnp.expm1(self.log_alpha_bar(t)))

    def lam(self, t: Array) -> Array:
        """Half log-SNR: lambda(t) = log(alpha(t) / sigma(t))."""
        log_ab = self.log_alpha_bar(t)
        return 0.5 * (log_ab - jnp.log(-jnp.expm1(log_ab)))

    # -- inverse lambda (needed by logSNR scheme and DPM-Solver) ------------
    def inv_lam(self, lam: Array) -> Array:
        """Invert lambda(t); generic bisection (schedules may override)."""
        lo = jnp.full_like(lam, 0.0)
        hi = jnp.full_like(lam, 1.0)

        def body(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            # lambda is decreasing in t
            go_right = self.lam(mid) > lam
            return (jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid))

        lo, hi = jax.lax.fori_loop(0, 64, body, (lo, hi))
        return 0.5 * (lo + hi)

    # -- DDIM / diffusion-ODE update coefficients (paper Eq. 8) -------------
    def ddim_coeffs(self, t_cur: Array, t_next: Array) -> tuple[Array, Array]:
        """Return (cx, ce) such that x_next = cx * x_cur + ce * eps."""
        a_cur, a_next = self.alpha(t_cur), self.alpha(t_next)
        s_cur, s_next = self.sigma(t_cur), self.sigma(t_next)
        cx = a_next / a_cur
        ce = s_next - cx * s_cur
        return cx, ce

    # -- discrete adapter ----------------------------------------------------
    def discrete_t(self, t: Array) -> Array:
        """Map continuous t in (0,1] to the discrete index in [0, T-1]."""
        return jnp.clip(
            jnp.round(t * self.num_train_steps - 1), 0, self.num_train_steps - 1
        ).astype(jnp.int32)


def linear_schedule(
    beta_start: float = 1e-4,
    beta_end: float = 2e-2,
    num_train_steps: int = 1000,
    t_end: float = 1e-3,
) -> NoiseSchedule:
    """Continuous interpolation of the DDPM linear-beta schedule.

    With beta(t) = beta_0 + t (beta_1 - beta_0) (betas scaled by T),
    log alpha_bar(t) = -0.5 * integral_0^t beta(s) ds
                     = -0.25 t^2 (b1 - b0) - 0.5 t b0
    where b0 = beta_start * T, b1 = beta_end * T.
    """
    b0 = beta_start * num_train_steps
    b1 = beta_end * num_train_steps

    def log_alpha_bar(t):
        t = jnp.asarray(t, jnp.float32)
        return -0.25 * t**2 * (b1 - b0) - 0.5 * t * b0

    sched = NoiseSchedule(
        name="linear",
        log_alpha_bar_fn=log_alpha_bar,
        t_end=t_end,
        num_train_steps=num_train_steps,
    )

    # Closed-form inverse lambda: t solves
    #   0.25 (b1-b0) t^2 + 0.5 b0 t + log_ab = 0   (log_ab < 0)
    def inv_lam_exact(lam):
        log_ab = -jax.nn.softplus(-2.0 * lam)
        a = 0.25 * (b1 - b0)
        b = 0.5 * b0
        c = log_ab
        return (-b + jnp.sqrt(b * b - 4 * a * c)) / (2 * a)

    object.__setattr__(sched, "inv_lam", inv_lam_exact)
    return sched


def cosine_schedule(s: float = 8e-3, t_end: float = 1e-3) -> NoiseSchedule:
    """Improved-DDPM cosine schedule, continuous form."""

    log_f0 = 2.0 * math.log(math.cos(s / (1 + s) * math.pi / 2))

    def log_alpha_bar(t):
        t = jnp.asarray(t, jnp.float32)
        f = jnp.cos((t + s) / (1 + s) * jnp.pi / 2)
        # clip to avoid log(0) at t=1
        return 2.0 * jnp.log(jnp.clip(f, 1e-6)) - log_f0

    return NoiseSchedule(name="cosine", log_alpha_bar_fn=log_alpha_bar, t_end=t_end)


def get_schedule(name: str, **kw) -> NoiseSchedule:
    if name == "linear":
        return linear_schedule(**kw)
    if name == "cosine":
        return cosine_schedule(**kw)
    raise ValueError(f"unknown schedule {name!r}")


# ---------------------------------------------------------------------------
# Timestep schemes: produce the solver grid {t_i}_{i=0}^{N}, t_0 = t_begin
# (noise) decreasing to t_N = t_end (data).  N = NFE for 1-eval-per-step
# solvers (DDIM, explicit Adams, ERA).
# ---------------------------------------------------------------------------


def timesteps(
    schedule: NoiseSchedule,
    num_steps: int,
    scheme: str = "uniform",
    t_begin: float | None = None,
    t_end: float | None = None,
) -> Array:
    """Return (num_steps + 1,) decreasing times from t_begin to t_end.

    All inputs are concrete, so the grid is forced to compile-time (eager)
    evaluation even when called mid-trace: a jitted program must embed the
    exact same floats a host-side caller (e.g. the executor building
    per-row ``StepMask`` grids) computes, not whatever XLA's constant
    folder produces for the staged-out construction.  The result is then
    wrapped in an ``optimization_barrier`` so downstream schedule
    transcendentals (``alpha``/``sigma``/``lam`` of grid times) evaluate
    at *runtime* under jit — XLA's constant folder rounds those chains
    differently than the runtime kernels, and mixed-NFE step masking
    (grids as runtime :class:`~repro.core.program.StepMask` inputs) must
    stay bitwise identical to the constant-grid fast path."""
    t0 = schedule.t_begin if t_begin is None else t_begin
    t1 = schedule.t_end if t_end is None else t_end
    with jax.ensure_compile_time_eval():
        if scheme == "uniform":
            ts = jnp.linspace(t0, t1, num_steps + 1)
        elif scheme == "quadratic":
            u = jnp.linspace(math.sqrt(t0), math.sqrt(t1), num_steps + 1)
            ts = u**2
        elif scheme == "logsnr":
            lam0 = schedule.lam(jnp.float32(t0))
            lam1 = schedule.lam(jnp.float32(t1))
            lams = jnp.linspace(lam0, lam1, num_steps + 1)
            # pin the endpoints exactly
            ts = schedule.inv_lam(lams).at[0].set(t0).at[-1].set(t1)
        else:
            raise ValueError(f"unknown timestep scheme {scheme!r}")
    return jax.lax.optimization_barrier(ts)
