"""ERA-Solver core: diffusion ODE solvers (the paper's contribution).

Public API:
    get_solver(name)                 -> sampling function
    get_program(name)                -> SolverProgram (the serving surface)
    SolverConfig / ERAConfig         -> solver options
    NoiseSchedule / get_schedule     -> VP noise schedules
    timesteps                        -> solver time grids
"""

from repro.core.dpm_adaptive import AdaptiveDPMConfig
from repro.core.era import ERAConfig, era_combine
from repro.core.program import SolverProgram
from repro.core.registry import (
    default_config,
    get_program,
    get_solver,
    solver_names,
)
from repro.core.schedules import (
    NoiseSchedule,
    cosine_schedule,
    get_schedule,
    linear_schedule,
    timesteps,
)
from repro.core.solver_base import SolverConfig, SolverOutput, ddim_step

__all__ = [
    "AdaptiveDPMConfig",
    "ERAConfig",
    "NoiseSchedule",
    "SolverConfig",
    "SolverOutput",
    "SolverProgram",
    "cosine_schedule",
    "ddim_step",
    "default_config",
    "era_combine",
    "get_program",
    "get_schedule",
    "get_solver",
    "linear_schedule",
    "solver_names",
    "timesteps",
]
