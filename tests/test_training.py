import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.training import OptimizerConfig, make_lm_train_step
from repro.training import checkpoint as ck
from repro.training import optimizer as opt


def test_adamw_quadratic_converges():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200, schedule="constant", weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init_state(params)
    target = jnp.array([1.0, 1.0])
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt.apply_updates(cfg, params, grads, state)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)


def test_grad_clipping():
    cfg = OptimizerConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = opt.init_state(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, m = opt.apply_updates(cfg, params, huge, state)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_no_weight_decay_on_vectors():
    cfg = OptimizerConfig(lr=0.1, weight_decay=1.0, warmup_steps=0, schedule="constant")
    params = {"scale": jnp.ones(8), "w": jnp.ones((8, 8))}
    state = opt.init_state(params)
    zero = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = opt.apply_updates(cfg, params, zero, state)
    np.testing.assert_allclose(np.asarray(p2["scale"]), 1.0)      # untouched
    assert float(jnp.max(p2["w"])) < 1.0                           # decayed


def test_lr_schedule_shapes():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine")
    lrs = [float(opt.lr_at(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6       # mid warmup
    assert abs(lrs[2] - 1.0) < 1e-6       # end warmup
    assert 0 < lrs[3] < 1.0
    assert lrs[4] < 1e-6                  # decayed out


def test_microbatching_matches_full_batch():
    """mu=1 and mu=4 produce (numerically) the same update."""
    cfg = get_config("llama3.2-1b", smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
    }
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    outs = {}
    for mu in (1, 4):
        step = make_lm_train_step(m, ocfg, microbatches=mu)
        p2, _, metrics = jax.jit(step)(
            params, opt.init_state(params), batch, jax.random.PRNGKey(2)
        )
        outs[mu] = (p2, float(metrics["loss"]))
    assert abs(outs[1][1] - outs[4][1]) < 1e-3
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_checkpoint_roundtrip_and_rotation():
    tree = {
        "params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
        "opt": {"step": np.int32(7)},
    }
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3, 4):
            ck.save_rotating(d, tree, step, keep=2)
        files = sorted(os.listdir(d))
        assert files == ["ckpt_00000003.npz", "ckpt_00000004.npz"]
        latest = ck.latest(d)
        got, step = ck.restore(latest)
        assert step == 4
        np.testing.assert_array_equal(got["params"]["w"], tree["params"]["w"])
        assert int(got["opt"]["step"]) == 7
