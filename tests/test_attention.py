import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models.attention import (
    _chunked_sdpa,
    _naive_sdpa,
    cache_insert,
    init_cache,
    sdpa,
)


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 3),           # batch
    st.sampled_from([(4, 2), (8, 4), (6, 1)]),  # (H, KV)
    st.integers(5, 40),          # Sq = Sk
    st.sampled_from([16, 32]),   # hd
    st.sampled_from([0, 7]),     # window
    st.sampled_from([3, 16]),    # chunk
)
def test_chunked_matches_naive(b, heads, s, hd, window, chunk):
    h, kv = heads
    q = _rand(0, b, s, h, hd)
    k = _rand(1, b, s, kv, hd)
    v = _rand(2, b, s, kv, hd)
    pos = jnp.arange(s)
    ref = _naive_sdpa(q, k, v, pos, pos, window=window, causal=True, softcap=0.0)
    got = _chunked_sdpa(
        q, k, v, pos, pos, window=window, causal=True, softcap=0.0, chunk=chunk
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_invalid_slots_masked():
    b, s, h, hd = 1, 8, 2, 16
    q = _rand(0, b, 1, h, hd)
    k = _rand(1, b, s, h, hd)
    v = _rand(2, b, s, h, hd)
    kv_pos = jnp.array([0, 1, 2, 3, -1, -1, -1, -1])
    out_masked = sdpa(q, k, v, jnp.array([3]), kv_pos, impl="naive")
    out_short = sdpa(
        q, k[:, :4], v[:, :4], jnp.array([3]), kv_pos[:4], impl="naive"
    )
    np.testing.assert_allclose(
        np.asarray(out_masked), np.asarray(out_short), atol=1e-5
    )


def test_ring_buffer_positions():
    cache = init_cache(1, 4, 1, 8, jnp.float32)
    for pos in range(7):
        k = jnp.full((1, 1, 1, 8), float(pos))
        cache = cache_insert(cache, k, k, jnp.int32(pos))
    # slots hold positions 4,5,6,3 (ring of 4)
    assert sorted(np.asarray(cache["pos"]).tolist()) == [3, 4, 5, 6]


def test_protected_slots_never_evicted():
    cache = init_cache(1, 6, 1, 4, jnp.float32)
    for pos in range(12):
        k = jnp.full((1, 1, 1, 4), float(pos))
        cache = cache_insert(cache, k, k, jnp.int32(pos), protected=2)
    pos_arr = np.asarray(cache["pos"])
    assert pos_arr[0] == 0 and pos_arr[1] == 1  # sinks retained
    assert set(pos_arr[2:]) == {8, 9, 10, 11}


def test_sliding_window_with_sinks():
    """Protected prefix stays visible outside the window."""
    b, s, h, hd = 1, 12, 1, 8
    q = _rand(0, b, 1, h, hd)
    k = _rand(1, b, s, h, hd)
    v = _rand(2, b, s, h, hd)
    kv_pos = jnp.arange(s)
    out = sdpa(
        q, k, v, jnp.array([11]), kv_pos,
        window=4, protected=2, impl="naive",
    )
    # equivalent dense computation over {0,1} U {8..11}
    keep = jnp.array([0, 1, 8, 9, 10, 11])
    out2 = sdpa(
        q, k[:, keep], v[:, keep], jnp.array([11]), kv_pos[keep], impl="naive"
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-5)


def test_softcap_changes_scores():
    b, s, h, hd = 1, 6, 2, 16
    q, k, v = _rand(0, b, s, h, hd), _rand(1, b, s, h, hd), _rand(2, b, s, h, hd)
    pos = jnp.arange(s)
    a = sdpa(q * 10, k, v, pos, pos, impl="naive")
    b_ = sdpa(q * 10, k, v, pos, pos, impl="naive", softcap=5.0)
    assert float(jnp.max(jnp.abs(a - b_))) > 1e-4


def test_int8_kv_cache_roundtrip():
    from repro.models.attention import _dequant, _quantize

    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32)) * 3.0
    q, s = _quantize(x)
    back = _dequant(q, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert q.dtype == jnp.int8
    assert rel < 0.02


def test_int8_kv_decode_matches_full():
    """int8 KV cache keeps decode logits within quantization noise of the
    full-precision cache (same token stream fed to both engines — token
    agreement on an untrained model is argmax-fragile and proves nothing)."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import Engine, ServeConfig

    key = jax.random.PRNGKey(0)
    cfg = get_config("llama3.2-1b", smoke=True)
    m_full = build_model(cfg)
    m_q = build_model(cfg.with_(kv_quant="int8"))
    params = m_full.init(key)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    eng_f = Engine(m_full, ServeConfig(max_len=64))
    eng_q = Engine(m_q, ServeConfig(max_len=64))
    batch = {"tokens": prompts}
    lf, cf = eng_f.prefill_step(params, batch)
    lq, cq = eng_q.prefill_step(params, batch)
    pos = cfg.num_meta_tokens + prompts.shape[1]
    for i in range(6):
        nxt = jnp.argmax(
            lf[:, -1, : cfg.vocab_size].astype(jnp.float32), axis=-1
        ).astype(jnp.int32)
        dec = {"tokens": nxt[:, None], "pos": jnp.int32(pos + i)}
        lf, cf = eng_f.decode_step(params, cf, dec)
        lq, cq = eng_q.decode_step(params, cq, dec)
        scale = float(jnp.max(jnp.abs(lf)).astype(jnp.float32)) + 1e-6
        err = float(jnp.max(jnp.abs(lf - lq)).astype(jnp.float32)) / scale
        # ~2% per-tensor int8 noise compounds across layers and steps;
        # a scale/layout bug would blow past 1.0
        assert err < 0.2, (i, err)


# ---------------------------------------------------------------------------
# per-row kv_mask: every impl carries it natively (mixed-seq-len serving)
# ---------------------------------------------------------------------------


def _lengths_mask(s, lengths):
    return jnp.arange(s)[None, :] < jnp.asarray(lengths, jnp.int32)[:, None]


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([(4, 2), (6, 1)]),  # (H, KV)
    st.integers(8, 40),                 # S
    st.sampled_from([0, 7]),            # window
    st.booleans(),                      # causal
    st.integers(0, 10_000),             # lengths seed
)
def test_masked_chunked_matches_masked_naive(heads, s, window, causal, lseed):
    h, kv = heads
    b = 3
    q = _rand(0, b, s, h, 16)
    k = _rand(1, b, s, kv, 16)
    v = _rand(2, b, s, kv, 16)
    pos = jnp.arange(s)
    lens = jax.random.randint(
        jax.random.PRNGKey(lseed), (b,), 0, s + 1
    ).tolist()
    lens[0] = s  # pin a full row
    mask = _lengths_mask(s, lens)
    ref = _naive_sdpa(
        q, k, v, pos, pos, window=window, causal=causal, softcap=0.0,
        kv_mask=mask,
    )
    got = _chunked_sdpa(
        q, k, v, pos, pos, window=window, causal=causal, softcap=0.0,
        chunk=16, kv_mask=mask,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_masked_pallas_and_banded_match_chunked():
    """sdpa-level wall: with kv_mask set, the pallas and banded fast paths
    agree with chunked on a windowed+sinks causal layout that exercises all
    three dispatches."""
    b, s, h, kv, hd = 3, 128, 4, 2, 32
    q = _rand(0, b, s, h, hd)
    k = _rand(1, b, s, kv, hd)
    v = _rand(2, b, s, kv, hd)
    pos = jnp.arange(s)
    mask = _lengths_mask(s, (128, 57, 0))
    kw = dict(window=32, causal=True, softcap=0.0, protected=2, kv_mask=mask)
    ref = sdpa(q, k, v, pos, pos, impl="chunked", chunk=64, **kw)
    banded = sdpa(q, k, v, pos, pos, impl="banded", **kw)  # s >= 4*window
    pallas = sdpa(q, k, v, pos, pos, impl="pallas", **kw)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(pallas), np.asarray(ref), atol=2e-5)
    assert not np.asarray(pallas[2]).any()  # all-pad row -> exact zeros


def test_fully_masked_rows_zero_on_all_impls():
    b, s, h, hd = 2, 16, 2, 16
    q, k, v = _rand(0, b, s, h, hd), _rand(1, b, s, h, hd), _rand(2, b, s, h, hd)
    pos = jnp.arange(s)
    mask = _lengths_mask(s, (0, 5))
    for impl in ("naive", "chunked", "pallas"):
        out = sdpa(q, k, v, pos, pos, causal=False, impl=impl, kv_mask=mask)
        assert not np.asarray(out[0]).any(), impl
        assert np.asarray(out[1]).any(), impl


# ---------------------------------------------------------------------------
# fallback machinery: loud, observable, and never fired by masked fast paths
# ---------------------------------------------------------------------------


def test_banded_layout_unmet_falls_back_loudly():
    import warnings as _warnings

    from repro.models import attention as A

    b, s, h, hd = 1, 16, 2, 8
    q, k, v = _rand(0, b, s, h, hd), _rand(1, b, s, h, hd), _rand(2, b, s, h, hd)
    pos = jnp.arange(s)
    events = []
    obs = A.register_fallback_observer(lambda i, r: events.append((i, r)))
    # the once-per-process warning may have fired in an earlier test: reset
    A._warned_fallbacks.discard(("banded", "banded-layout-unmet"))
    try:
        with _warnings.catch_warnings(record=True) as caught:
            _warnings.simplefilter("always")
            out = sdpa(q, k, v, pos, pos, causal=False, impl="banded")
        assert events == [("banded", "banded-layout-unmet")]
        msgs = [str(w.message) for w in caught if w.category is RuntimeWarning]
        assert any("falling back to chunked" in m for m in msgs)
        ref = sdpa(q, k, v, pos, pos, causal=False, impl="chunked")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        # second hit: observer fires again, warning does not
        with _warnings.catch_warnings(record=True) as caught2:
            _warnings.simplefilter("always")
            sdpa(q, k, v, pos, pos, causal=False, impl="banded")
        assert len(events) == 2
        assert not [w for w in caught2 if w.category is RuntimeWarning]
    finally:
        A.unregister_fallback_observer(obs)


def test_masked_fast_paths_do_not_fire_fallback():
    """The whole point of the tentpole: kv_mask on pallas/banded/chunked is
    native, so no fallback observer fires for masked traffic."""
    from repro.models import attention as A

    b, s, h, hd = 2, 128, 2, 16
    q, k, v = _rand(0, b, s, h, hd), _rand(1, b, s, h, hd), _rand(2, b, s, h, hd)
    pos = jnp.arange(s)
    mask = _lengths_mask(s, (128, 40))
    events = []
    obs = A.register_fallback_observer(lambda i, r: events.append((i, r)))
    try:
        sdpa(q, k, v, pos, pos, causal=False, impl="pallas", kv_mask=mask)
        sdpa(q, k, v, pos, pos, causal=True, window=32, impl="banded",
             kv_mask=mask)
        sdpa(q, k, v, pos, pos, causal=False, impl="chunked", kv_mask=mask)
        sdpa(q, k, v, pos, pos, causal=False, impl="auto", kv_mask=mask)
    finally:
        A.unregister_fallback_observer(obs)
    assert events == []
