"""Front-door wall: the HTTP serving surface and the queue policies under
it.

* wire schema — versioned round-trip of the SampleRequest/SampleResult
  dataclass pair, unknown-field and version rejection, bit-exact array
  codec;
* admission control — burst past ``max_queue_rows`` yields 429 +
  ``Retry-After`` while already-admitted requests still complete;
* deadlines — a queued request past ``deadline_ms`` fails fast with
  DeadlineExceededError (504 over the wire), without poisoning the queue;
* priority — higher-priority requests board a launch first under a fake
  clock (``drain_once()``, no threads, no sleeps);
* loopback end-to-end — a wire request's x0 is bit-identical to the same
  seed through the in-process SamplerService, through the same
  ``build_engine`` factory path;
* observability — /metrics exposes the serving instruments, /healthz
  reports scheduler stats, errors map to typed JSON.

All engine tests use the analytic OracleDenoiser: exact, fast, no params.
"""

import json
import threading
import time
from http.client import HTTPConnection

import numpy as np
import pytest

from conftest import AnalyticGaussian, OracleDenoiser
from repro.core import linear_schedule
from repro.serving import (
    AsyncBatchedSampler,
    DeadlineExceededError,
    EngineConfig,
    FrontDoor,
    FrontDoorClient,
    QueueFullError,
    SampleRequest,
    SamplerService,
    SchedulerPolicy,
    SchemaError,
    build_engine,
    decode_request,
    decode_result,
    encode_request,
    encode_result,
    serve_frontdoor,
    result_keys as K,
)
from repro.serving.frontdoor import SCHEMA_VERSION, decode_array, encode_array

ANALYTIC = AnalyticGaussian()
D_MODEL = OracleDenoiser.D_MODEL
CFG = EngineConfig(nfe=6, k=3, batch_buckets=(1, 2, 4))


def make_engine(**overrides):
    cfg = EngineConfig(
        **{**{f: getattr(CFG, f) for f in CFG.__dataclass_fields__},
           **overrides}
    )
    return build_engine(OracleDenoiser(ANALYTIC), linear_schedule(), cfg)


def req(seed=0, batch=1, seq_len=6, nfe=6, **kw):
    return SampleRequest(batch=batch, seq_len=seq_len, nfe=nfe, seed=seed, **kw)


# ---------------------------------------------------------------------------
# wire schema (pure: no server, no engine)
# ---------------------------------------------------------------------------


def test_array_codec_bit_exact():
    for arr in (
        np.random.default_rng(0).standard_normal((3, 4, 5)).astype(np.float32),
        np.arange(7, dtype=np.int32),
        np.array([np.nan, np.inf, -0.0, 1e-45], dtype=np.float32),
        np.random.default_rng(1).standard_normal((2, 2)),  # float64
    ):
        back = decode_array(encode_array(arr))
        assert back.dtype == arr.dtype and back.shape == arr.shape
        np.testing.assert_array_equal(
            back.view(np.uint8), arr.view(np.uint8)
        )  # bit-exact, NaNs included


def test_request_round_trip_exact_fields():
    r = req(seed=9, batch=3, solver="ddim", priority=2, deadline_ms=125.0)
    wire = json.loads(json.dumps(encode_request(r)))
    assert wire["v"] == SCHEMA_VERSION
    assert decode_request(wire) == r


def test_request_unknown_field_rejected():
    wire = encode_request(req())
    wire["prioritty"] = 7  # misspelled: must NOT silently sample at default
    with pytest.raises(SchemaError, match="prioritty"):
        decode_request(wire)


def test_request_version_rejected():
    wire = encode_request(req())
    for v in (None, 0, SCHEMA_VERSION + 1, "1"):
        bad = {**wire, "v": v}
        with pytest.raises(SchemaError, match="schema version"):
            decode_request(bad)
    with pytest.raises(SchemaError):
        decode_request([wire])  # non-object payload


def test_request_field_types_validated():
    wire = encode_request(req())
    for field, bad in (
        ("batch", "2"), ("seed", 1.5), ("priority", True),
        ("deadline_ms", "soon"), ("solver", 3),
    ):
        with pytest.raises(SchemaError, match=field):
            decode_request({**wire, field: bad})
    with pytest.raises(SchemaError):  # missing required field
        decode_request({k: v for k, v in wire.items() if k != "batch"})


def test_result_round_trip_bit_exact():
    engine = make_engine()
    _, fut = engine.submit_with_future(req(seed=3, batch=2))
    engine.drain(None)
    res = fut.result()
    back = decode_result(json.loads(json.dumps(encode_result(res))))
    np.testing.assert_array_equal(np.asarray(res.x0), back.x0)
    assert set(back.aux) == set(res.aux)
    for k in res.aux:
        np.testing.assert_array_equal(np.asarray(res.aux[k]), back.aux[k])
    assert back.latency_s == res.latency_s
    assert back.padded_batch == res.padded_batch
    wire = encode_result(res)
    with pytest.raises(SchemaError, match="unknown result"):
        decode_result({**wire, "extra": 1})
    with pytest.raises(SchemaError, match="missing result"):
        decode_result({k: v for k, v in wire.items() if k != "x0"})


# ---------------------------------------------------------------------------
# queue policy: priority + deadlines under a fake clock (no threads)
# ---------------------------------------------------------------------------


def make_manual_sched(policy=None, **engine_overrides):
    """Unstarted scheduler + fake clock: submit stamps arrival at clk[0],
    drain_once(now=...) is the only pump."""
    clk = [0.0]
    sched = AsyncBatchedSampler(
        make_engine(**engine_overrides),
        params=None,
        policy=policy or SchedulerPolicy(max_wait_ms=10.0),
        clock=lambda: clk[0],
    )
    return sched, clk


def test_priority_boards_first():
    """Three 1-row requests, bucket ladder max 2: the priority-5 request
    boards the first (full) launch even though it arrived last; the
    middle arrival overflows to a second launch."""
    sched, clk = make_manual_sched(batch_buckets=(1, 2))
    futs = [
        sched.submit(req(seed=0, priority=0)),
        sched.submit(req(seed=1, priority=0)),
        sched.submit(req(seed=2, priority=5)),
    ]
    clk[0] = 1.0  # past max_wait_ms -> queue is ready
    # one max-bucket chunk per queue per pass; the overflow row launches
    # on the next pass
    assert sched.drain_once(now=clk[0]) == 1
    assert sched.drain_once(now=clk[0]) == 1
    sizes = [f.result(timeout=5).padded_batch for f in futs]
    # boarding order (-priority, arrival): [2, 0] fuse, [1] overflows
    assert sizes == [2, 1, 2]


def test_priority_orders_ready_queues():
    """Two ready fuse-group queues: the one holding the most urgent
    request launches first (observable through batch completion order via
    the shared executor's serialized run)."""
    sched, clk = make_manual_sched(batch_buckets=(1, 2))
    order = []
    lo = sched.submit(req(seed=0, nfe=6, priority=0))
    hi = sched.submit(req(seed=1, nfe=7, priority=3))  # different fuse group
    lo.add_done_callback(lambda f: order.append("lo"))
    hi.add_done_callback(lambda f: order.append("hi"))
    clk[0] = 1.0
    sched.drain_once(now=clk[0])
    assert order == ["hi", "lo"]


def test_deadline_expired_fails_fast():
    sched, clk = make_manual_sched()
    doomed = sched.submit(req(seed=0, deadline_ms=50.0))
    healthy = sched.submit(req(seed=1))
    clk[0] = 0.2  # 200ms > 50ms deadline
    sched.drain_once(now=clk[0])
    with pytest.raises(DeadlineExceededError, match="expired in queue"):
        doomed.result(timeout=5)
    assert healthy.result(timeout=5).x0.shape == (1, 6, D_MODEL)
    m = sched.engine.metrics.get("sampler_deadline_expired_total")
    assert m.value() == 1.0


def test_deadline_not_expired_is_untouched():
    sched, clk = make_manual_sched()
    fut = sched.submit(req(seed=0, deadline_ms=500.0))
    clk[0] = 0.1  # inside the deadline
    sched.drain_once(now=clk[0])
    assert fut.result(timeout=5).x0.shape == (1, 6, D_MODEL)


def test_deadline_validated_at_submit():
    engine = make_engine()
    for bad in (0.0, -5.0, float("inf"), float("nan"), "soon"):
        with pytest.raises(ValueError, match="deadline_ms"):
            engine.submit_with_future(req(deadline_ms=bad))
    for bad in (1.5, "high", True):
        with pytest.raises(ValueError, match="priority"):
            engine.submit_with_future(req(priority=bad))


def test_seed_validated_at_submit():
    """A seed PRNGKey cannot fold (outside int64 — JSON ints are
    unbounded) must be rejected at submit, not explode at drain time
    inside a fused batch."""
    engine = make_engine()
    for bad in (2**63, -(2**63) - 1, 2**200):
        with pytest.raises(ValueError, match="seed"):
            engine.submit_with_future(req(seed=bad))
    for bad in (1.5, "7", True):
        with pytest.raises(ValueError, match="seed"):
            engine.submit_with_future(req(seed=bad))
    # the extremes of the accepted range sample fine
    for ok in (2**63 - 1, -(2**63)):
        _, fut = engine.submit_with_future(req(seed=ok))
        engine.drain(None)
        assert fut.result().x0.shape == (1, 6, D_MODEL)


def test_resource_caps_validated_at_submit():
    """Server-side maxima on wire-exposed resource fields: an admitted
    request must never be able to force a multi-GB allocation or an
    unbounded jit cache at drain."""
    engine = make_engine(max_batch=4, max_nfe=8, max_seq_len=16)
    with pytest.raises(ValueError, match="max_batch"):
        engine.submit_with_future(req(batch=5))
    with pytest.raises(ValueError, match="max_nfe"):
        engine.submit_with_future(req(nfe=9))
    with pytest.raises(ValueError, match="max_seq_len"):
        engine.submit_with_future(req(seq_len=17))
    # at the caps everything still runs
    _, fut = engine.submit_with_future(req(batch=4, nfe=8, seq_len=16))
    engine.drain(None)
    assert fut.result().x0.shape == (4, 16, D_MODEL)
    # a seq-bucket ladder takes over bounding the sequence axis: the
    # ladder top (not max_seq_len) is the contract
    bucketed = make_engine(
        max_seq_len=16, seq_buckets=(8,), batch_buckets=(1, 2)
    )
    with pytest.raises(ValueError, match="seq bucket"):
        bucketed.submit_with_future(req(seq_len=9))
    # caps are opt-out for trusted in-process callers
    unbounded = make_engine(max_batch=None, max_nfe=None, max_seq_len=None)
    _, fut = unbounded.submit_with_future(req(batch=5, nfe=9, seq_len=17))
    unbounded.drain(None)
    assert fut.result().x0.shape == (5, 17, D_MODEL)


def test_admission_bound_rejects_then_recovers():
    """Burst past max_queue_rows: the overflow submit raises QueueFullError
    (with a retry hint) while admitted requests complete; afterwards the
    drained queue admits again."""
    sched, clk = make_manual_sched(
        policy=SchedulerPolicy(max_wait_ms=10.0, max_queue_rows=2)
    )
    admitted = [sched.submit(req(seed=s)) for s in range(2)]
    with pytest.raises(QueueFullError) as ei:
        sched.submit(req(seed=9))
    assert ei.value.rows == 2 and ei.value.limit == 2
    assert ei.value.retry_after_s >= 1.0
    clk[0] = 1.0
    sched.drain_once(now=clk[0])
    for f in admitted:
        assert f.result(timeout=5).x0.shape == (1, 6, D_MODEL)
    fut = sched.submit(req(seed=10))  # drained queue admits again
    clk[0] = 2.0
    sched.drain_once(now=clk[0])
    assert fut.result(timeout=5).x0.shape == (1, 6, D_MODEL)
    m = sched.engine.metrics.get("sampler_admission_rejects_total")
    assert m.value(solver="era", seq=6, nfe=6) == 1.0


def test_submit_int_ticket_deprecated():
    engine = make_engine()
    with pytest.warns(DeprecationWarning, match="submit_with_future"):
        ticket = engine.submit(req(seed=0))
    fut = engine.future(ticket)
    engine.drain(None)
    assert fut.result().x0.shape == (1, 6, D_MODEL)


# ---------------------------------------------------------------------------
# HTTP server: loopback end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture()
def door():
    d = serve_frontdoor(
        make_engine(), params=None, policy=SchedulerPolicy(max_wait_ms=5.0)
    )
    yield d
    d.stop()


def test_wire_matches_in_process_bit_identical(door):
    """The acceptance check: a loopback wire request returns x0 bit-
    identical to the same request through the in-process SamplerService,
    both engines built by the same factory config."""
    r = req(seed=7, batch=2)
    wire = FrontDoorClient(door.url, timeout=60).sample(r)
    local = SamplerService(engine=make_engine()).sample(None, r)
    np.testing.assert_array_equal(np.asarray(local.x0), wire.x0)
    assert wire.x0.dtype == np.asarray(local.x0).dtype
    for k in local.aux:
        np.testing.assert_array_equal(
            np.asarray(local.aux[k]), wire.aux[k]
        )
    assert wire.info[K.PADDED_BATCH] == 2


def test_wire_concurrent_requests_fuse_and_stay_isolated(door):
    """Concurrent wire requests fuse in the server's scheduler, and each
    still gets its own seed's solo-identical rows."""
    client = FrontDoorClient(door.url, timeout=60)
    out = {}

    def call(seed):
        out[seed] = client.sample(req(seed=seed))

    threads = [threading.Thread(target=call, args=(s,)) for s in (11, 12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for seed in (11, 12):
        solo = SamplerService(engine=make_engine()).sample(
            None, req(seed=seed)
        )
        np.testing.assert_array_equal(np.asarray(solo.x0), out[seed].x0)


def test_wire_deadline_maps_to_504():
    """A wire request whose deadline expires in queue gets the typed 504.
    Unstarted scheduler: the handler blocks while we expire the queue by
    hand — deterministic, no racing the drain thread."""
    clk = [0.0]
    sched = AsyncBatchedSampler(
        make_engine(), params=None,
        policy=SchedulerPolicy(max_wait_ms=10.0), clock=lambda: clk[0],
    )
    with FrontDoor(sched) as d:
        client = FrontDoorClient(d.url, timeout=60)
        err = {}

        def call():
            try:
                client.sample(req(seed=0, deadline_ms=20.0))
            except Exception as e:  # noqa: BLE001 - asserting on it below
                err["e"] = e

        th = threading.Thread(target=call)
        th.start()
        deadline = time.time() + 10
        while sched.pending == 0 and time.time() < deadline:
            time.sleep(0.005)
        clk[0] = 1.0  # way past 20ms
        sched.drain_once(now=clk[0])
        th.join(timeout=10)
    assert isinstance(err.get("e"), DeadlineExceededError)
    # the reconstructed exception carries the server's message (with the
    # actual waited time), not a client-side "waited nanms" placeholder
    assert "expired in queue" in str(err["e"])
    assert "nan" not in str(err["e"])


def test_wire_burst_429_while_inflight_completes():
    """Burst beyond the policy's queue depth over HTTP: overflow requests
    get 429 + Retry-After while the admitted in-flight requests complete
    with 200.  Unstarted scheduler makes the full/drained states exact."""
    clk = [0.0]
    sched = AsyncBatchedSampler(
        make_engine(), params=None,
        policy=SchedulerPolicy(max_wait_ms=10.0, max_queue_rows=2),
        clock=lambda: clk[0],
    )
    with FrontDoor(sched) as d:
        client = FrontDoorClient(d.url, timeout=60)
        results, errors = {}, {}

        def call(seed):
            try:
                results[seed] = client.sample(req(seed=seed))
            except Exception as e:  # noqa: BLE001 - asserting on it below
                errors[seed] = e

        inflight = [
            threading.Thread(target=call, args=(s,)) for s in (0, 1)
        ]
        for t in inflight:
            t.start()
        deadline = time.time() + 10
        while sched.pending < 2 and time.time() < deadline:
            time.sleep(0.005)
        assert sched.pending == 2

        # raw HTTP for the overflow: assert status + Retry-After header
        conn = HTTPConnection(d.host, d.port, timeout=30)
        conn.request(
            "POST", "/v1/sample",
            json.dumps(encode_request(req(seed=9))).encode(),
        )
        resp = conn.getresponse()
        assert resp.status == 429
        assert int(resp.getheader("Retry-After")) >= 1
        body = json.loads(resp.read())
        assert body["error"]["type"] == "queue_full"
        conn.close()

        # and via the client: the typed exception, carrying the *server's*
        # message (queue key + row counts), not placeholder attributes
        with pytest.raises(QueueFullError) as ei:
            client.sample(req(seed=10))
        assert "is full" in str(ei.value)
        assert "None" not in str(ei.value) and "-1" not in str(ei.value)
        assert ei.value.retry_after_s >= 1.0

        clk[0] = 1.0
        sched.drain_once(now=clk[0])  # in-flight completes
        for t in inflight:
            t.join(timeout=30)
    assert not errors
    assert sorted(results) == [0, 1]
    for seed, res in results.items():
        solo = SamplerService(engine=make_engine()).sample(
            None, req(seed=seed)
        )
        np.testing.assert_array_equal(np.asarray(solo.x0), res.x0)


def test_http_error_mapping(door):
    conn = HTTPConnection(door.host, door.port, timeout=30)
    # bad JSON -> 400
    conn.request("POST", "/v1/sample", b"{not json")
    r = conn.getresponse()
    assert r.status == 400
    assert json.loads(r.read())["error"]["type"] == "invalid_request"
    # unknown field -> 400
    conn.request(
        "POST", "/v1/sample",
        json.dumps({**encode_request(req()), "bogus": 1}).encode(),
    )
    r = conn.getresponse()
    assert r.status == 400 and r.read()
    # semantic validation (unknown solver) -> 400, at submit, server-side
    conn.request(
        "POST", "/v1/sample",
        json.dumps({**encode_request(req()), "solver": "nope"}).encode(),
    )
    r = conn.getresponse()
    assert r.status == 400 and r.read()
    # unknown route -> 404
    conn.request("GET", "/nope")
    r = conn.getresponse()
    assert r.status == 404
    assert json.loads(r.read())["error"]["type"] == "not_found"
    conn.close()


def test_wire_poison_request_400_not_500(door):
    """A request that used to explode at drain time (seed past int64 —
    JSON ints are unbounded — or an allocation-bomb batch/nfe) now gets a
    400 at admission, and a co-batched innocent request still completes:
    the 'invalid requests raise at submit' invariant holds on the wire."""
    client = FrontDoorClient(door.url, timeout=60)
    out = {}

    def good():
        out["res"] = client.sample(req(seed=21))

    th = threading.Thread(target=good)
    th.start()
    conn = HTTPConnection(door.host, door.port, timeout=30)
    for field, value in (
        ("seed", 2**63), ("seed", -(2**63) - 1),
        ("batch", 10**8), ("nfe", 10**7), ("seq_len", 10**6),
    ):
        conn.request(
            "POST", "/v1/sample",
            json.dumps({**encode_request(req()), field: value}).encode(),
        )
        r = conn.getresponse()
        body = json.loads(r.read())
        assert r.status == 400, (field, value)
        assert body["error"]["type"] == "invalid_request"
    conn.close()
    th.join(timeout=60)
    solo = SamplerService(engine=make_engine()).sample(None, req(seed=21))
    np.testing.assert_array_equal(np.asarray(solo.x0), out["res"].x0)


def test_idle_keepalive_connection_reclaimed():
    """An idle persistent connection (or one trickling a body) must not
    pin a handler thread forever: past idle_timeout_s the server closes
    the socket.  In-flight samples are unaffected — they block on the
    scheduler Future, not the socket."""
    import socket

    sched = AsyncBatchedSampler(
        make_engine(), params=None, policy=SchedulerPolicy(max_wait_ms=5.0)
    )
    sched.start()
    try:
        with FrontDoor(sched, idle_timeout_s=0.3) as d:
            # a request on a keep-alive connection still works...
            conn = HTTPConnection(d.host, d.port, timeout=30)
            conn.request(
                "POST", "/v1/sample",
                json.dumps(encode_request(req(seed=31))).encode(),
            )
            r = conn.getresponse()
            assert r.status == 200
            r.read()
            # ...then the idle connection is closed by the server
            sock = conn.sock
            sock.settimeout(10)
            assert sock.recv(1) == b""  # EOF, not a hang
            conn.close()
            # raw socket that never sends a request line: same reclaim
            s = socket.create_connection((d.host, d.port), timeout=10)
            assert s.recv(1) == b""
            s.close()
    finally:
        sched.stop()


class _FakeHandler:
    """Just enough of BaseHTTPRequestHandler for FrontDoor._handle: records
    status codes sent, optionally blows up mid-body-write."""

    def __init__(self, path, fail_body_write=False):
        self.path = path
        self.headers = {}
        self.close_connection = False
        self.codes = []
        self._fail = fail_body_write
        outer = self

        class _W:
            def write(self, data):
                if outer._fail:
                    raise ConnectionResetError("peer reset mid-body")

        self.wfile = _W()

    def send_response(self, code):
        self.codes.append(code)

    def send_header(self, *a):
        pass

    def end_headers(self):
        pass


def test_partial_response_failure_does_not_append_500():
    """A socket failure after the 200 status line has been sent must not
    append a second status line (stream corruption on a keep-alive
    connection): the server just drops the connection.  A failure *before*
    any response still gets the 500 body."""
    sched = AsyncBatchedSampler(
        make_engine(), params=None, policy=SchedulerPolicy(max_wait_ms=5.0)
    )
    door = FrontDoor(sched)
    try:
        # mid-write failure: exactly one status line, connection dropped
        h = _FakeHandler("/healthz", fail_body_write=True)
        door._handle(h, "GET")
        assert h.codes == [200]
        assert h.close_connection is True
        # pre-response failure: the 500 reply is still sent
        door.scheduler.stats = lambda: (_ for _ in ()).throw(
            RuntimeError("boom")
        )
        h2 = _FakeHandler("/healthz")
        door._handle(h2, "GET")
        assert h2.codes == [500]
    finally:
        door._server.server_close()
        sched.stop()


def test_metrics_and_healthz(door):
    client = FrontDoorClient(door.url, timeout=60)
    client.sample(req(seed=1))
    health = client.healthz()
    assert health["ok"] is True
    assert health["stats"][K.SUBMITTED] >= 1
    text = client.metrics()
    for name in (
        "sampler_queue_depth_rows",
        "sampler_fuse_occupancy_ratio",
        "sampler_compile_cache_hits_total",
        "sampler_compile_cache_misses_total",
        "sampler_compile_programs_total",
        "sampler_compile_seconds",
        "sampler_warmup_grid_programs",
        "sampler_warmup_compiled_programs",
        "sampler_warmup_in_progress",
        "sampler_warmup_duration_seconds",
        "sampler_warmup_programs_total",
        "sampler_admission_rejects_total",
        "sampler_requests_submitted_total",
        "sampler_request_latency_seconds_bucket",
        "frontdoor_http_requests_total",
    ):
        assert name in text, name
    # exposition format: HELP/TYPE headers and histogram plumbing
    assert "# TYPE sampler_request_latency_seconds histogram" in text
    assert 'le="+Inf"' in text
    assert text.endswith("\n")


def test_client_rejects_non_http_url():
    with pytest.raises(ValueError, match="base_url"):
        FrontDoorClient("ftp://example:1")
