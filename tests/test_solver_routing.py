"""Per-request solver routing through the solver-agnostic serving stack.

PR-4 regression wall: ``SampleRequest.solver`` used to be accepted at
submit and silently ignored — every request ran the engine's default
solver.  After the solver-program refactor it routes: each request runs
its named registry solver's program, mixed-solver traffic batches per
solver (never cross-contaminating a fused bucket), unknown names are
rejected at ``submit()``, and each program's own ``validate`` enforces its
(batch, nfe) constraints with a solver-specific message.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import OracleDenoiser
from repro.core import ERAConfig, default_config, get_solver
from repro.serving import (
    AsyncBatchedSampler,
    BatchedSampler,
    SampleRequest,
    SamplerService,
    SchedulerPolicy,
)

D_MODEL = OracleDenoiser.D_MODEL


@pytest.fixture()
def engine(analytic):
    return BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=(2, 4, 8)
    )


def _x_init(seed, batch, seq_len=6):
    return jax.random.normal(
        jax.random.PRNGKey(seed), (batch, seq_len, D_MODEL), jnp.float32
    )


def _solo(analytic, solver, seed, batch, nfe, seq_len=6):
    """Reference run of one request through the engine-default config of
    its solver (per-sample ERS for era — the serving default)."""
    cfg = default_config(solver, nfe=nfe)
    if solver == "era":
        cfg = dataclasses.replace(cfg, per_sample=True)
    return get_solver(solver)(
        analytic.eps, _x_init(seed, batch, seq_len), analytic.schedule, cfg
    )


# ---------------------------------------------------------------------------
# routing (the satellite regression: req.solver used to be ignored)
# ---------------------------------------------------------------------------


def test_mixed_solver_requests_in_one_drain_route_correctly(engine, analytic):
    """Two requests with different ``solver`` fields in one drain() come
    back from *their own* solvers, not the engine default."""
    t_era = engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, seed=1))
    t_ddim = engine.submit(
        SampleRequest(batch=2, seq_len=6, nfe=8, solver="ddim", seed=2)
    )
    t_pp2m = engine.submit(
        SampleRequest(batch=1, seq_len=6, nfe=8, solver="dpm_solver_pp2m", seed=3)
    )
    results = engine.drain(params=None)
    for ticket, solver, seed, batch in (
        (t_era, "era", 1, 1),
        (t_ddim, "ddim", 2, 2),
        (t_pp2m, "dpm_solver_pp2m", 3, 1),
    ):
        ref = _solo(analytic, solver, seed, batch, nfe=8)
        np.testing.assert_allclose(
            np.asarray(results[ticket].x0),
            np.asarray(ref.x0),
            atol=1e-5,
            err_msg=f"{solver} request did not route to {solver}",
        )
    # and the solvers genuinely differ (routing is observable)
    assert (
        np.max(
            np.abs(
                np.asarray(results[t_ddim].x0[:1])
                - np.asarray(results[t_pp2m].x0)
            )
        )
        > 1e-4
    )


def test_mixed_solver_requests_never_share_a_fused_chunk(
    engine, analytic, monkeypatch
):
    """Same shape, different solvers: the drain groups per solver, so each
    executed chunk is solver-homogeneous (no bucket cross-contamination)."""
    chunks = []
    orig = engine.executor.run_chunk

    def recording(params, seq_len, nfe, chunk, results, pad=True):
        chunks.append({req.solver or "era" for _, req, _ in chunk})
        return orig(params, seq_len, nfe, chunk, results, pad=pad)

    monkeypatch.setattr(engine.executor, "run_chunk", recording)
    for seed, solver in enumerate([None, "ddim", None, "ddim", "era"]):
        engine.submit(
            SampleRequest(batch=1, seq_len=6, nfe=8, solver=solver, seed=seed)
        )
    engine.drain(params=None)
    assert len(chunks) == 2  # one era chunk (None+era), one ddim chunk
    for solvers in chunks:
        assert len(solvers) == 1


def test_unknown_solver_rejected_at_submit_not_drain(engine):
    with pytest.raises(ValueError, match="unknown solver"):
        engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, solver="nope"))
    assert engine.pending == 0  # nothing queued to poison the drain


def test_unknown_solver_rejected_at_scheduler_submit(engine):
    sched = AsyncBatchedSampler(engine, params=None)
    with pytest.raises(ValueError, match="unknown solver"):
        sched.submit(SampleRequest(batch=1, seq_len=6, nfe=8, solver="nope"))
    sched.stop()


def test_jit_cache_keys_carry_the_solver(engine):
    """Same (batch, seq_len, nfe) bucket, different solvers -> different
    compiled programs, each compiled once."""
    for solver in (None, "ddim", None, "ddim"):
        engine.submit(
            SampleRequest(batch=1, seq_len=6, nfe=8, solver=solver, seed=0)
        )
        engine.drain(params=None)
    cache = engine.compile_cache()
    assert sorted(k[0] for k in cache) == ["ddim", "era"]
    # entries are AOT-compiled executables: one entry == one compile; the
    # repeat submits above were memory hits, not recompiles
    for runner in cache.values():
        assert isinstance(runner, jax.stages.Compiled)
    stats = engine.compile_stats()
    assert stats["fresh"] + stats["disk"] == 2
    assert stats["memory"] == 2


def test_sampler_service_routes_request_solver(analytic):
    """The facade serves a request naming a different solver than its own
    default — per-request routing reaches the one-call surface too."""
    svc = SamplerService(OracleDenoiser(analytic), analytic.schedule, "era")
    x0 = svc.sample(
        None, SampleRequest(batch=2, seq_len=6, nfe=8, solver="ddim", seed=5)
    ).x0
    ref = get_solver("ddim")(
        analytic.eps, _x_init(5, 2), analytic.schedule,
        default_config("ddim", nfe=8),
    )
    np.testing.assert_allclose(np.asarray(x0), np.asarray(ref.x0), atol=1e-5)


# ---------------------------------------------------------------------------
# per-program validate (the satellite: constraints moved out of the executor)
# ---------------------------------------------------------------------------


def test_era_validate_rejects_nfe_below_k(engine):
    with pytest.raises(ValueError, match="nfe >= k"):
        engine.submit(SampleRequest(batch=1, seq_len=6, nfe=3, solver="era"))


def test_pece_validate_rejects_sub_budget_nfe(engine):
    with pytest.raises(ValueError, match="2 NFE per PECE step"):
        engine.submit(
            SampleRequest(batch=1, seq_len=6, nfe=1, solver="implicit_adams_pece")
        )
    # nfe=2 (one PECE step) is the smallest legal budget
    t = engine.submit(
        SampleRequest(batch=1, seq_len=6, nfe=2, solver="implicit_adams_pece")
    )
    res = engine.drain(params=None)[t]
    assert res.x0.shape == (1, 6, D_MODEL)


def test_pp2m_validate_rejects_warmup_starved_nfe(engine):
    with pytest.raises(ValueError, match="order-1 warmup"):
        engine.submit(
            SampleRequest(batch=1, seq_len=6, nfe=1, solver="dpm_solver_pp2m")
        )


def test_batch_and_nfe_floor_validation(engine):
    with pytest.raises(ValueError, match="batch must be >= 1"):
        engine.submit(SampleRequest(batch=0, seq_len=6, nfe=8))
    with pytest.raises(ValueError, match="nfe must be >= 1"):
        engine.submit(SampleRequest(batch=1, seq_len=6, nfe=0, solver="ddim"))


def test_shared_delta_era_route_is_not_fusable(analytic):
    """A request routed to the engine's shared-delta ERA config still runs
    exact-size/unfused (program.fusable consults the routed config)."""
    eng = BatchedSampler(
        OracleDenoiser(analytic),
        analytic.schedule,
        solver_config=ERAConfig(per_sample=False),
        batch_buckets=(8,),
    )
    t1 = eng.submit(SampleRequest(batch=2, seq_len=6, nfe=10, solver="era", seed=1))
    t2 = eng.submit(SampleRequest(batch=1, seq_len=6, nfe=10, solver="ddim", seed=2))
    results = eng.drain(params=None)
    assert results[t1].padded_batch == 2  # exact size: era not fusable here
    assert results[t2].padded_batch == 8  # ddim stays fusable: pads to bucket


# ---------------------------------------------------------------------------
# scheduler: mixed-solver continuous batching
# ---------------------------------------------------------------------------


def test_scheduler_serves_mixed_solver_stream(engine, analytic):
    """A mixed era/ddim/dpm++2m stream through the async scheduler: every
    future resolves to its own solver's result."""
    stream = [
        ("era", 0), ("ddim", 1), ("dpm_solver_pp2m", 2),
        ("ddim", 3), ("era", 4), ("dpm_solver_pp2m", 5),
    ]
    with AsyncBatchedSampler(
        engine,
        params=None,
        policy=SchedulerPolicy(max_wait_ms=2.0, target_occupancy=0.5),
    ) as sched:
        futs = [
            sched.submit(
                SampleRequest(batch=1, seq_len=6, nfe=8, solver=s, seed=seed)
            )
            for s, seed in stream
        ]
        results = [f.result(timeout=120) for f in futs]
    for (solver, seed), res in zip(stream, results):
        ref = _solo(analytic, solver, seed, batch=1, nfe=8)
        np.testing.assert_allclose(
            np.asarray(res.x0), np.asarray(ref.x0), atol=1e-5,
            err_msg=f"scheduler misrouted {solver} seed={seed}",
        )


def test_no_era_special_cases_left_in_serving_layer():
    """Acceptance wall: the serving layer is solver-agnostic — no
    isinstance(..., ERAConfig) (or any ERAConfig import) survives in
    serving/."""
    import os

    import repro.serving as serving_pkg

    serving_dir = os.path.dirname(serving_pkg.__file__)
    for fname in os.listdir(serving_dir):
        if not fname.endswith(".py"):
            continue
        with open(os.path.join(serving_dir, fname)) as f:
            for line in f:
                code = line.split("#", 1)[0]
                assert not (
                    "isinstance" in code and "ERAConfig" in code
                ), f"{fname}: {line.strip()}"
                assert not (
                    "import" in code and "ERAConfig" in code
                ), f"{fname} still imports ERAConfig: {line.strip()}"
