"""Right-pad prefix-safety walls for the mixed-seq-len masking contract.

``MASKABLE_BLOCKS`` admits SSM / recurrent kinds on the argument that every
cross-position mixing they do is a strictly directional (left-to-right)
scan, so zero right-padding can never reach a prefix position's output
(contract note in :mod:`repro.models.ssm`).  These tests pin that argument
empirically, at two levels:

* **module level** — the raw scan blocks (mamba, mlstm, slstm) run on a
  zero-right-padded input reproduce the exact-shape run BITWISE on the
  valid prefix.
* **model level** — every smoke architecture family's DiffusionLM ``eps``
  on a padded batch with ``lengths`` set reproduces the exact-shape batch
  BITWISE on the prefix, with the pad tail exactly zero.  This is the
  property the serving engine's seq-bucketing relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, ssm
from repro.models.diffusion import DiffusionLM
from repro.models.layers import init_params

SMOKE_FAMILIES = [
    "llama3.2-1b",          # dense attention (control)
    "xlstm-350m",           # mlstm + slstm scans
    "hymba-1.5b",           # mamba + attention hybrid
    "deepseek-v2-lite-16b", # MLA + MoE
    "whisper-base",         # enc + xdec (causal self-attention)
]


# ---------------------------------------------------------------------------
# module level: raw directional scans
# ---------------------------------------------------------------------------


def _padded_vs_exact(fn, x, l_exact):
    """Run fn on x[:, :l_exact] and on x (right-padded with zeros); return
    both outputs as numpy."""
    exact = fn(x[:, :l_exact])
    padded = fn(x)
    return np.asarray(exact), np.asarray(padded)


@pytest.mark.parametrize("kind", ["mamba", "mlstm", "slstm"])
def test_scan_blocks_prefix_bitwise(kind):
    arch = {"mamba": "hymba-1.5b", "mlstm": "xlstm-350m", "slstm": "xlstm-350m"}
    cfg = get_config(arch[kind], smoke=True)
    key = jax.random.PRNGKey(0)
    b, s, l_exact = 2, 9, 5
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), cfg.dtype)
    x = x.at[:, l_exact:].set(0.0)  # zero right-padding
    if kind == "mamba":
        p = init_params(ssm.mamba_specs(cfg), key, cfg.param_dtype)
        fn = lambda xi: ssm.mamba(p, xi, cfg)[0]
    elif kind == "mlstm":
        p = init_params(ssm.mlstm_specs(cfg), key, cfg.param_dtype)
        fn = lambda xi: ssm.mlstm_block(p, xi, cfg)[0]
    else:
        p = init_params(ssm.slstm_specs(cfg), key, cfg.param_dtype)
        fn = lambda xi: ssm.slstm_block(p, xi, cfg)[0]
    exact, padded = _padded_vs_exact(fn, x, l_exact)
    np.testing.assert_array_equal(
        padded[:, :l_exact], exact,
        err_msg=f"{kind}: right-padding leaked into the prefix",
    )


def test_associative_scan_prefix_tree_is_length_stable():
    """The subtle half of the argument: jax.lax.associative_scan's combine
    tree for prefix position p must not change when the scan length grows
    (Brent–Kung — each prefix output depends only on its own index).  If a
    future jax version reshapes the tree by total length, this trips before
    any model-level wall does."""
    a = jax.random.uniform(jax.random.PRNGKey(0), (1, 16, 4), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 4), jnp.float32)
    h0 = jnp.zeros((1, 4), jnp.float32)
    for l_exact in (3, 7, 12):
        he, _ = ssm.chunked_linear_scan(
            a[:, :l_exact], b[:, :l_exact], h0, chunk=4
        )
        hp, _ = ssm.chunked_linear_scan(a, b, h0, chunk=4)
        np.testing.assert_array_equal(
            np.asarray(hp)[:, :l_exact], np.asarray(he), err_msg=str(l_exact)
        )


# ---------------------------------------------------------------------------
# model level: DiffusionLM eps on every smoke family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", SMOKE_FAMILIES)
def test_dlm_eps_prefix_bitwise(arch):
    """Padded + masked eps == exact-shape eps BITWISE on the prefix, pad
    tail exactly zero — for attention, SSM, MLA, and encoder families."""
    cfg = get_config(arch, smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    assert dlm.supports_length_masking, arch
    params = dlm.init(jax.random.PRNGKey(0))
    b, l_exact, l_pad = 2, 5, 9
    x = jax.random.normal(jax.random.PRNGKey(1), (b, l_exact, cfg.d_model))
    xp = jnp.concatenate(
        [x, jnp.zeros((b, l_pad - l_exact, cfg.d_model))], axis=1
    )
    t = jnp.float32(0.7)
    lengths = jnp.full((b,), l_exact, jnp.int32)
    e_exact = np.asarray(dlm.eps(params, x, t))
    e_exact_masked = np.asarray(dlm.eps(params, x, t, lengths=lengths))
    e_pad = np.asarray(dlm.eps(params, xp, t, lengths=lengths))
    # masking an already-exact batch is a numerical no-op (+0.0 biases)
    np.testing.assert_array_equal(e_exact_masked, e_exact, err_msg=arch)
    np.testing.assert_array_equal(
        e_pad[:, :l_exact], e_exact,
        err_msg=f"{arch}: padding changed prefix eps",
    )
    assert (e_pad[:, l_exact:] == 0.0).all(), arch


@pytest.mark.parametrize("arch", ["xlstm-350m", "deepseek-v2-lite-16b"])
def test_dlm_eps_ragged_rows_match_solo(arch):
    """Ragged per-row lengths: each valid row of a masked padded batch
    matches that row's solo exact-shape run within the documented 1e-6
    parity bar (solo runs compile separately, so bitwise isn't promised
    across program boundaries)."""
    cfg = get_config(arch, smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(jax.random.PRNGKey(0))
    lens = (3, 8, 5)
    s = max(lens)
    x = jax.random.normal(jax.random.PRNGKey(2), (len(lens), s, cfg.d_model))
    valid = jnp.arange(s)[None, :] < jnp.asarray(lens)[:, None]
    x = jnp.where(valid[..., None], x, 0.0)
    t = jnp.float32(0.4)
    e_pad = np.asarray(
        dlm.eps(params, x, t, lengths=jnp.asarray(lens, jnp.int32))
    )
    for i, L in enumerate(lens):
        solo = np.asarray(dlm.eps(params, x[i : i + 1, :L], t))[0]
        np.testing.assert_allclose(
            e_pad[i, :L], solo, atol=1e-6, err_msg=f"{arch} row={i}"
        )
        assert (e_pad[i, L:] == 0.0).all()
