"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (deliverable c).

All kernels run in interpret mode on CPU; the same call sites compile for
TPU unchanged.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.era import AM4
from repro.core.lagrange import lagrange_weights
from repro.kernels import ops, ref


def _rand(seed, shape, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, H, KV, Sq, Sk, hd, window, causal, softcap, dtype)
    (2, 4, 2, 128, 128, 64, 0, True, 0.0, jnp.float32),
    (1, 8, 8, 256, 256, 128, 0, False, 0.0, jnp.float32),
    (2, 4, 1, 100, 100, 48, 0, True, 0.0, jnp.float32),       # MQA + ragged
    (1, 6, 3, 130, 130, 80, 32, True, 0.0, jnp.float32),      # window
    (1, 4, 4, 64, 64, 64, 16, True, 0.0, jnp.bfloat16),       # bf16
    (2, 2, 2, 96, 96, 64, 0, True, 30.0, jnp.float32),        # softcap
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_ref(case):
    b, h, kv, sq, sk, hd, window, causal, cap, dtype = case
    q = _rand(0, (b, sq, h, hd), dtype)
    k = _rand(1, (b, sk, kv, hd), dtype)
    v = _rand(2, (b, sk, kv, hd), dtype)
    qpos, kpos = jnp.arange(sq), jnp.arange(sk)
    out = ops.flash_attention(
        q, k, v, qpos, kpos, window=window, causal=causal, softcap=cap
    )
    r = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).astype(jnp.float32),
        k.transpose(0, 2, 1, 3).astype(jnp.float32),
        v.transpose(0, 2, 1, 3).astype(jnp.float32),
        qpos, kpos, window=window, causal=causal, softcap=cap,
    ).transpose(0, 2, 1, 3)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(r, np.float32), atol=atol
    )


@settings(max_examples=8, deadline=None)
@given(
    st.integers(1, 2),
    st.sampled_from([(2, 1), (4, 2), (4, 4)]),
    st.integers(17, 150),
    st.sampled_from([32, 64, 96]),
    st.sampled_from([0, 24]),
)
def test_flash_attention_hypothesis(b, heads, s, hd, window):
    h, kv = heads
    q = _rand(3, (b, s, h, hd))
    k = _rand(4, (b, s, kv, hd))
    v = _rand(5, (b, s, kv, hd))
    pos = jnp.arange(s)
    out = ops.flash_attention(q, k, v, pos, pos, window=window)
    r = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), pos, pos, window=window,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 8, 2, 256, 64, 0, 0, jnp.float32),
    (1, 4, 4, 300, 128, 64, 0, jnp.float32),
    (2, 6, 3, 200, 80, 32, 4, jnp.float32),
    (1, 25, 5, 130, 64, 48, 8, jnp.float32),   # hymba head counts
    (2, 8, 1, 256, 64, 0, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_vs_ref(case):
    b, h, kv, s, hd, window, prot, dtype = case
    q = _rand(0, (b, h, hd), dtype)
    k = _rand(1, (b, s, kv, hd), dtype)
    v = _rand(2, (b, s, kv, hd), dtype)
    kv_pos = jnp.where(jnp.arange(s) < s - 10, jnp.arange(s), -1)
    qpos = jnp.int32(s - 11)
    out = ops.decode_attention(
        q, k, v, qpos, kv_pos, window=window, protected=prot
    )
    r = ref.decode_attention_ref(
        q.astype(jnp.float32),
        k.transpose(0, 2, 1, 3).astype(jnp.float32),
        v.transpose(0, 2, 1, 3).astype(jnp.float32),
        qpos, kv_pos, window=window, protected=prot,
    )
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(r, np.float32), atol=atol
    )


def test_decode_matches_flash_single_row():
    """Decode kernel == flash kernel with Sq=1 on the same cache."""
    b, h, kv, s, hd = 1, 4, 2, 128, 64
    q = _rand(0, (b, h, hd))
    k = _rand(1, (b, s, kv, hd))
    v = _rand(2, (b, s, kv, hd))
    kv_pos = jnp.arange(s)
    qpos = jnp.int32(s - 1)
    dec = ops.decode_attention(q, k, v, qpos, kv_pos)
    fl = ops.flash_attention(
        q[:, None], k, v, jnp.array([s - 1]), kv_pos, causal=True
    )[:, 0]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fl), atol=3e-5)


# ---------------------------------------------------------------------------
# fused ERA update
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    st.integers(2, 6),                     # k order
    st.sampled_from([(64,), (3, 17, 5), (2, 130)]),
    st.sampled_from([64, 256]),
)
def test_era_step_vs_ref(k_order, shape, block):
    x = _rand(0, shape)
    eps_sel = _rand(1, (k_order,) + shape)
    t_sel = jnp.linspace(0.9, 0.2, k_order)
    e_hist = _rand(2, (3,) + shape)
    t_next = jnp.float32(0.15)
    cx, ce = jnp.float32(0.97), jnp.float32(-0.05)
    am4 = jnp.asarray(AM4, jnp.float32)
    xn, eb = ops.era_step(x, eps_sel, t_sel, e_hist, t_next, cx, ce, am4, block=block)
    w = lagrange_weights(t_sel, t_next)
    xr, er = ref.era_update_ref(
        x.reshape(-1), eps_sel.reshape(k_order, -1), w,
        e_hist.reshape(3, -1), am4, cx, ce,
    )
    np.testing.assert_allclose(np.asarray(xn).reshape(-1), np.asarray(xr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(eb).reshape(-1), np.asarray(er), atol=2e-5)


def test_era_combine_drop_in():
    from repro.core.era import era_combine as core_combine

    k_order = 4
    eps_sel = _rand(1, (k_order, 8, 4))
    t_sel = jnp.array([0.9, 0.7, 0.5, 0.3])
    e_hist = _rand(2, (3, 8, 4))
    t_next = jnp.float32(0.25)
    eb1, ec1 = core_combine(eps_sel, t_sel, e_hist, t_next)
    eb2, ec2 = ops.era_combine(eps_sel, t_sel, e_hist, t_next)
    np.testing.assert_allclose(np.asarray(eb1), np.asarray(eb2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(ec1), np.asarray(ec2), atol=2e-5)


# ---------------------------------------------------------------------------
# masked flash attention (per-row kv_mask operand — mixed-seq-len serving)
# ---------------------------------------------------------------------------


def _ragged_mask(s, lengths):
    return jnp.arange(s)[None, :] < jnp.asarray(lengths, jnp.int32)[:, None]


MASKED_FLASH_CASES = [
    # (H, KV, S, hd, window, causal, softcap, protected, lengths)
    # lengths sweep ragged rows including all-pad (0) and full-length rows
    (4, 2, 128, 64, 0, True, 0.0, 0, (128, 57, 0)),
    (4, 2, 128, 64, 0, False, 0.0, 0, (128, 57, 0)),     # denoiser layout
    (8, 8, 256, 128, 0, False, 0.0, 0, (200, 1)),
    (4, 1, 100, 48, 0, True, 0.0, 0, (99, 31)),          # MQA + ragged shape
    (6, 3, 130, 80, 32, True, 0.0, 4, (120, 77)),        # window + sinks
    (2, 2, 96, 64, 0, True, 30.0, 0, (96, 5)),           # softcap
]


@pytest.mark.parametrize("case", MASKED_FLASH_CASES)
def test_masked_flash_attention_vs_masked_refs(case):
    """Masked Pallas kernel vs BOTH masked oracles: the pure-jnp ref and
    the masked chunked-SDPA streaming softmax.  All-pad rows come back
    exactly zero on every impl."""
    from repro.models.attention import _chunked_sdpa

    h, kv, s, hd, window, causal, cap, prot, lengths = case
    b = len(lengths)
    q = _rand(0, (b, s, h, hd))
    k = _rand(1, (b, s, kv, hd))
    v = _rand(2, (b, s, kv, hd))
    pos = jnp.arange(s)
    mask = _ragged_mask(s, lengths)
    out = ops.flash_attention(
        q, k, v, pos, pos, kv_mask=mask,
        window=window, causal=causal, softcap=cap, protected=prot,
    )
    r = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), pos, pos,
        window=window, causal=causal, softcap=cap, protected=prot,
        kv_mask=mask,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=2e-5)
    c = _chunked_sdpa(
        q, k, v, pos, pos, window=window, causal=causal, softcap=cap,
        chunk=64, protected=prot, kv_mask=mask,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(c), atol=2e-5)
    for row, n in enumerate(lengths):
        if n == 0:
            assert not np.asarray(out[row]).any(), "all-pad row must be zero"


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([(2, 1), (4, 2), (4, 4), (6, 3)]),   # GQA group sizes
    st.integers(17, 150),                                # seq len
    st.sampled_from([32, 64, 96]),                       # head dim
    st.sampled_from([(0, 0, True), (0, 0, False), (24, 0, True),
                     (24, 4, True)]),                    # window/sinks/causal
    st.sampled_from([0.0, 20.0]),                        # softcap
    st.integers(0, 10_000),                              # lengths seed
)
def test_masked_flash_attention_hypothesis(heads, s, hd, wpc, cap, lseed):
    """Hypothesis sweep of the masked kernel across GQA group sizes,
    window/causal, softcap, protected sinks, and ragged per-row lengths —
    always including an all-pad row and a full-length row."""
    h, kv = heads
    window, prot, causal = wpc
    b = 4
    q = _rand(6, (b, s, h, hd))
    k = _rand(7, (b, s, kv, hd))
    v = _rand(8, (b, s, kv, hd))
    pos = jnp.arange(s)
    lkey = jax.random.PRNGKey(lseed)
    lens = jax.random.randint(lkey, (b,), 0, s + 1).tolist()
    lens[0], lens[1] = s, 0      # pin the edge rows
    mask = _ragged_mask(s, lens)
    out = ops.flash_attention(
        q, k, v, pos, pos, kv_mask=mask,
        window=window, causal=causal, softcap=cap, protected=prot,
    )
    r = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), pos, pos,
        window=window, causal=causal, softcap=cap, protected=prot,
        kv_mask=mask,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=3e-5)
    assert not np.asarray(out[1]).any()


def test_masked_flash_padding_invariance_bitwise():
    """The serving property the mask exists for: a row right-padded from L
    to S with kv_mask runs BIT-IDENTICAL (on its valid slice) to the same
    row's exact-shape unmasked kernel run — extra fully-masked kv blocks
    rescale the online-softmax state by exp(0) == 1.0 exactly."""
    b, h, kv, hd, s = 1, 4, 2, 64, 96
    for L in (1, 31, 64, 95):
        q = _rand(10, (b, s, h, hd))
        k = _rand(11, (b, s, kv, hd))
        v = _rand(12, (b, s, kv, hd))
        for causal in (False, True):
            exact = ops.flash_attention(
                q[:, :L], k[:, :L], v[:, :L],
                jnp.arange(L), jnp.arange(L), causal=causal,
            )
            padded = ops.flash_attention(
                q, k, v, jnp.arange(s), jnp.arange(s),
                kv_mask=_ragged_mask(s, [L]), causal=causal,
            )
            np.testing.assert_array_equal(
                np.asarray(padded[:, :L]), np.asarray(exact),
                err_msg=f"L={L} causal={causal}",
            )


def test_unmasked_flash_unchanged_by_mask_plumbing():
    """kv_mask=None and an all-valid kv_mask agree with each other and the
    unmasked oracle (the mask operand costs nothing when absent)."""
    b, h, kv, s, hd = 2, 4, 2, 128, 64
    q, k, v = _rand(0, (b, s, h, hd)), _rand(1, (b, s, kv, hd)), _rand(2, (b, s, kv, hd))
    pos = jnp.arange(s)
    out_none = ops.flash_attention(q, k, v, pos, pos)
    out_full = ops.flash_attention(
        q, k, v, pos, pos, kv_mask=jnp.ones((b, s), bool)
    )
    np.testing.assert_array_equal(np.asarray(out_none), np.asarray(out_full))
