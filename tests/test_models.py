"""Per-architecture smoke tests (reduced same-family configs, CPU) +
decode-vs-teacher-forcing consistency — deliverable (f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_names, get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=24):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (b, cfg.frontend.num_positions, cfg.d_model)
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.frontend.num_positions, cfg.d_model)
        )
    return batch


def _dropless(cfg):
    if cfg.moe:
        return cfg.with_(
            moe=dataclasses.replace(cfg.moe, dispatch="dense_mix")
        )
    return cfg


@pytest.mark.parametrize("name", arch_names())
def test_smoke_forward_and_loss(name):
    """Reduced variant: one forward + loss, shapes right, finite."""
    cfg = get_config(name, smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    logits, aux = m.forward(params, batch)
    prefix = cfg.num_meta_tokens + (
        cfg.frontend.num_positions if cfg.family == "vlm" else 0
    )
    assert logits.shape == (2, 24 + prefix, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, aux = m.loss(params, batch)
    assert jnp.isfinite(loss)


@pytest.mark.parametrize("name", arch_names())
def test_smoke_train_step(name):
    """One optimizer step runs and produces finite grads/params."""
    from repro.training import OptimizerConfig, make_lm_train_step
    from repro.training.optimizer import init_state

    cfg = get_config(name, smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    step = make_lm_train_step(m, OptimizerConfig(lr=1e-3, total_steps=10))
    p2, opt2, metrics = jax.jit(step)(
        params, init_state(params), _batch(cfg), KEY
    )
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually moved
    delta = sum(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("name", arch_names())
def test_decode_matches_forward(name):
    """Prefill + token-by-token decode reproduces teacher-forcing logits
    (MoE archs compared under the dropless reference dispatch)."""
    cfg = _dropless(get_config(name, smoke=True))
    m = build_model(cfg)
    params = m.init(KEY)
    b, s, split = 2, 16, 12
    batch = _batch(cfg, b, s)
    tokens = batch["tokens"]
    logits_full, _ = m.forward(params, batch)
    lg, cache = m.prefill(params, dict(batch, tokens=tokens[:, :split]), 64)
    off = cfg.num_meta_tokens + (
        cfg.frontend.num_positions if cfg.family == "vlm" else 0
    )
    # prefill last-token logits match
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(logits_full[:, off + split - 1]),
        atol=2e-3,
    )
    errs = []
    for t in range(split, s):
        lg, cache = m.decode(
            params, cache, {"tokens": tokens[:, t : t + 1], "pos": jnp.int32(off + t)}
        )
        errs.append(
            float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, off + t])))
        )
    assert max(errs) < 2e-3, (name, errs)


def test_param_counts_full_configs():
    """Full (non-smoke) configs build abstract params with sane sizes."""
    expected = {
        "llama3.2-1b": (1.2e9, 1.9e9),
        "qwen2-1.5b": (1.4e9, 2.3e9),
        "whisper-base": (0.05e9, 0.45e9),  # incl. 268M long-ctx pos table
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "xlstm-350m": (0.25e9, 0.6e9),
        "mixtral-8x7b": (45e9, 50e9),
        "deepseek-67b": (64e9, 72e9),
        "hymba-1.5b": (1.2e9, 2.2e9),
        "paligemma-3b": (2.0e9, 3.5e9),
        "minitron-4b": (4.0e9, 6.0e9),
    }
    for name, (lo, hi) in expected.items():
        m = build_model(get_config(name))
        n = m.param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
