"""Batched sampling engine: fused-step parity (+ broken-kernel fallback),
compile-once-per-bucket, batch-of-N == N-independent-runs equivalence
(per-sample ERS on), padding invariance, and mesh-sharded drain parity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import OracleDenoiser, run_mesh_subprocess
from repro.core import ERAConfig, get_solver
from repro.core import era as era_mod
from repro.kernels import ops
from repro.serving import BatchedSampler, SampleRequest, fused_path_ok

D_MODEL = OracleDenoiser.D_MODEL


@pytest.fixture()
def engine(analytic):
    return BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=(2, 4, 8)
    )


# ---------------------------------------------------------------------------
# fused default path numerics (acceptance: <= 1e-5 in f32, interpret mode)
# ---------------------------------------------------------------------------


def test_fused_step_parity_within_1e5():
    for shape in ((4, 96), (2, 8, 8), (130,)):
        for k in (3, 4, 6):
            err = ops.fused_step_parity(shape=shape, k=k)
            assert err <= 1e-5, (shape, k, err)


def test_fused_path_ok_gate():
    assert fused_path_ok()


def test_parity_gate_active_in_float32():
    """The gate is actually on for this backend: the f32 parity probe is
    within tolerance and core resolves the fused ops module (not the jnp
    fallback)."""
    assert ops.fused_step_parity() <= era_mod._FUSED_TOL
    backend = jax.default_backend()
    assert era_mod._fused_ops() is not None
    assert era_mod._FUSED_OK[backend] is True


def test_gate_first_consulted_inside_jit_trace_is_not_poisoned(monkeypatch):
    """The probe cannot execute under an ambient jit trace; a fresh process
    whose first gate consultation happens mid-trace must defer (jnp path
    for that trace) WITHOUT caching a failure, so the next eager check
    still enables the kernel.  Regression: this used to cache False and
    silently disable the fused path process-wide."""
    monkeypatch.setattr(era_mod, "_FUSED_OK", {})  # fresh-process cache

    @jax.jit
    def traced(z):
        assert era_mod._fused_ops() is None  # deferred, not probed
        return z

    traced(jnp.zeros(()))
    assert jax.default_backend() not in era_mod._FUSED_OK  # unpoisoned
    assert fused_path_ok()  # eager probe now enables the kernel


def test_engine_enables_fused_path_from_fresh_process(monkeypatch, analytic):
    """The engine's jitted-bucket path probes the gate eagerly before
    tracing, so a process that only ever serves compiled drains still gets
    the fused kernel."""
    monkeypatch.setattr(era_mod, "_FUSED_OK", {})
    eng = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=(2,)
    )
    eng.submit(SampleRequest(batch=1, seq_len=6, nfe=6, seed=0))
    eng.drain(params=None)
    assert era_mod._FUSED_OK[jax.default_backend()] is True


def test_broken_kernel_silently_falls_back_to_jnp(monkeypatch, analytic):
    """A kernel that fails the parity probe must degrade to the pure-jnp
    combine — same samples as use_fused_update=False, never garbage — and
    report fused_path_ok() is False."""

    def broken_era_step(x, eps_sel, t_sel, e_hist, t_next, cx, ce, am4, **kw):
        return x + 1e3, eps_sel[0] + 1e3

    monkeypatch.setattr(ops, "era_step", broken_era_step)
    monkeypatch.setattr(era_mod, "_FUSED_OK", {})  # force a fresh probe
    assert fused_path_ok() is False

    cfg = ERAConfig(nfe=8, per_sample=True)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 6, D_MODEL), jnp.float32)
    out = get_solver("era")(analytic.eps, x, analytic.schedule, cfg)
    assert not bool(jnp.any(jnp.isnan(out.x0)))
    ref = get_solver("era")(
        analytic.eps,
        x,
        analytic.schedule,
        dataclasses.replace(cfg, use_fused_update=False),
    )
    np.testing.assert_array_equal(np.asarray(out.x0), np.asarray(ref.x0))


def test_gate_recovers_after_restore(analytic):
    """The monkeypatched probe above must not poison the session cache."""
    assert fused_path_ok()


# ---------------------------------------------------------------------------
# batched engine semantics
# ---------------------------------------------------------------------------


def test_submit_drain_shapes_and_metadata(engine, analytic):
    t1 = engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, seed=1))
    t2 = engine.submit(SampleRequest(batch=3, seq_len=6, nfe=8, seed=2))
    assert engine.pending == 2
    results = engine.drain(params=None)
    assert engine.pending == 0
    assert set(results) == {t1, t2}
    assert results[t1].x0.shape == (1, 6, D_MODEL)
    assert results[t2].x0.shape == (3, 6, D_MODEL)
    # 1 + 3 samples pad to the 4-bucket, fused into one batch
    assert results[t1].padded_batch == 4
    assert results[t1].batch_wall_s == results[t2].batch_wall_s
    assert results[t1].latency_s >= results[t1].batch_wall_s
    for res in results.values():
        assert not bool(jnp.any(jnp.isnan(res.x0)))
        assert "delta_eps_history" in res.aux
        # diagnostics are scoped to the request's own rows, not the padded
        # batch (no batch-mate rows, no pad rows in the mean)
        assert res.aux["delta_eps_history_per_sample"].shape == (
            8,
            res.x0.shape[0],
        )
        assert res.aux["delta_eps_history"].shape == (8,)


def test_batch_of_n_equals_independent_runs(engine, analytic):
    """Co-batched requests (per-sample ERS) match solo ERA-Solver runs."""
    seeds = [3, 4, 5]
    tickets = {
        s: engine.submit(SampleRequest(batch=1, seq_len=6, nfe=10, seed=s))
        for s in seeds
    }
    results = engine.drain(params=None)
    cfg = ERAConfig(nfe=10, per_sample=True)
    for s in seeds:
        x_init = jax.random.normal(
            jax.random.PRNGKey(s), (1, 6, D_MODEL), jnp.float32
        )
        solo = get_solver("era")(analytic.eps, x_init, analytic.schedule, cfg)
        np.testing.assert_allclose(
            np.asarray(results[tickets[s]].x0),
            np.asarray(solo.x0),
            atol=1e-5,
        )


def test_compile_once_per_bucket(engine):
    """Fluctuating request sizes within one bucket reuse one XLA program."""
    for seed, batch in enumerate((1, 2, 1, 2, 1)):
        engine.submit(SampleRequest(batch=batch, seq_len=6, nfe=8, seed=seed))
        engine.drain(params=None)
    cache = engine.compile_cache()
    assert len(cache) == 1  # batches 1 and 2 share the 2-bucket
    (runner,) = cache.values()
    # the cache holds AOT-compiled executables, not lazy jit wrappers, so
    # one entry *is* one compile; the remaining drains were memory hits
    assert isinstance(runner, jax.stages.Compiled)
    stats = engine.compile_stats()
    assert stats["fresh"] + stats["disk"] == 1
    assert stats["memory"] == 4


def test_distinct_buckets_compile_separately(engine):
    engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, seed=0))
    engine.submit(SampleRequest(batch=1, seq_len=4, nfe=8, seed=1))
    engine.submit(SampleRequest(batch=1, seq_len=6, nfe=12, seed=2))
    res = engine.drain(params=None)
    assert len(res) == 3
    assert len(engine.compile_cache()) == 3  # (seq 6, 8) / (seq 4, 8) / (seq 6, 12)


def test_oversize_request_chunks_to_max_bucket(engine):
    big = engine.submit(SampleRequest(batch=5, seq_len=6, nfe=8, seed=0))
    small = engine.submit(SampleRequest(batch=2, seq_len=6, nfe=8, seed=1))
    res = engine.drain(params=None)
    assert res[big].x0.shape == (5, 6, D_MODEL)
    assert res[small].x0.shape == (2, 6, D_MODEL)


def test_drain_chunk_failure_resolves_futures_and_spares_other_chunks(
    engine, analytic, monkeypatch
):
    """A chunk that fails mid-drain must not orphan any waiter: its tickets'
    futures carry the exception, other chunks still deliver, and drain()
    re-raises for its own caller.  Regression: a raise used to skip the
    future-resolution loop entirely, hanging cross-thread waiters forever."""
    orig = engine.executor.run_chunk

    def flaky(params, seq_len, nfe, chunk, results, pad=True):
        if seq_len == 4:
            raise RuntimeError("injected chunk failure")
        return orig(params, seq_len, nfe, chunk, results, pad=pad)

    monkeypatch.setattr(engine.executor, "run_chunk", flaky)
    bad = engine.submit(SampleRequest(batch=1, seq_len=4, nfe=8, seed=0))
    good = engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, seed=1))
    bad_fut, good_fut = engine.future(bad), engine.future(good)
    with pytest.raises(RuntimeError, match="injected"):
        engine.drain(params=None)
    with pytest.raises(RuntimeError, match="injected"):
        bad_fut.result(timeout=0)
    assert good_fut.result(timeout=0).x0.shape == (1, 6, D_MODEL)
    # delivery popped the futures: late lookups fail loudly, not silently
    with pytest.raises(KeyError, match="already delivered"):
        engine.future(good)


def test_shared_delta_config_not_fused(analytic):
    """Paper-default (shared delta_eps) configs couple the batch through one
    global error norm, so the engine must serve them unfused and unpadded —
    each request's result matches a solo run of exactly that request."""
    eng = BatchedSampler(
        OracleDenoiser(analytic),
        analytic.schedule,
        solver_config=ERAConfig(per_sample=False),
        batch_buckets=(8,),
    )
    t1 = eng.submit(SampleRequest(batch=2, seq_len=6, nfe=10, seed=11))
    t2 = eng.submit(SampleRequest(batch=1, seq_len=6, nfe=10, seed=12))
    results = eng.drain(params=None)
    assert results[t1].padded_batch == 2  # exact size, no pad, no fusion
    assert results[t2].padded_batch == 1
    for seed, ticket, batch in ((11, t1, 2), (12, t2, 1)):
        x_init = jax.random.normal(
            jax.random.PRNGKey(seed), (batch, 6, D_MODEL), jnp.float32
        )
        solo = get_solver("era")(
            analytic.eps, x_init, analytic.schedule, ERAConfig(nfe=10)
        )
        np.testing.assert_allclose(
            np.asarray(results[ticket].x0), np.asarray(solo.x0), atol=1e-5
        )


def test_padding_rows_do_not_leak(engine, analytic):
    """A request fused with pad rows equals the same request run alone."""
    t = engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, seed=7))
    padded = engine.drain(params=None)[t]
    assert padded.padded_batch == 2
    solo_engine = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=None
    )
    t2 = solo_engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, seed=7))
    solo = solo_engine.drain(params=None)[t2]
    assert solo.padded_batch == 1
    np.testing.assert_allclose(
        np.asarray(padded.x0), np.asarray(solo.x0), atol=1e-5
    )


@pytest.mark.parametrize("bucket", [8, 64])
def test_padding_invariance_at_serving_buckets(bucket, analytic):
    """drain() results are identical whether a request's group was padded up
    to the serving bucket (8 or 64) or run exact-size — the pad rows are
    inert for every real row."""
    padded_eng = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=(bucket,)
    )
    exact_eng = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=None
    )
    reqs = [(2, 21), (3, 22)]  # 5 rows -> 3 or 61 pad rows
    tp = [
        padded_eng.submit(SampleRequest(batch=b, seq_len=6, nfe=6, seed=s))
        for b, s in reqs
    ]
    te = [
        exact_eng.submit(SampleRequest(batch=b, seq_len=6, nfe=6, seed=s))
        for b, s in reqs
    ]
    res_p = padded_eng.drain(params=None)
    res_e = exact_eng.drain(params=None)
    for (b, _), tick_p, tick_e in zip(reqs, tp, te):
        assert res_p[tick_p].padded_batch == bucket
        assert res_e[tick_e].padded_batch == 5  # the fused exact group
        assert res_p[tick_p].x0.shape == (b, 6, D_MODEL)
        np.testing.assert_allclose(
            np.asarray(res_p[tick_p].x0),
            np.asarray(res_e[tick_e].x0),
            atol=1e-6,
        )


# ---------------------------------------------------------------------------
# mesh-sharded drain (tentpole acceptance: parity with the single-device
# engine on 8 virtual CPU devices)
# ---------------------------------------------------------------------------


def test_shared_delta_on_mesh_rejects_non_dp_batches(mesh8, analytic):
    """Shared-delta (per_sample=False) requests run exact-size — padding
    would change the global error norm — so on a mesh their batch must be a
    dp multiple.  Regression: this used to bypass dp rounding and silently
    degrade the whole drain to replicated placement."""
    eng = BatchedSampler(
        OracleDenoiser(analytic),
        analytic.schedule,
        solver_config=ERAConfig(per_sample=False),
        batch_buckets=(8,),
        mesh=mesh8,
    )
    with pytest.raises(ValueError, match="data-parallel"):
        eng.submit(SampleRequest(batch=3, seq_len=6, nfe=10, seed=0))
    assert eng.pending == 0  # the rejected request never queued

    # a dp-multiple batch is accepted, runs exact-size AND sharded, and
    # matches the single-device engine
    t = eng.submit(SampleRequest(batch=8, seq_len=6, nfe=10, seed=1))
    res = eng.drain(params=None)[t]
    assert res.padded_batch == 8
    assert len(res.x0.sharding.device_set) == 8  # not replicated
    solo = BatchedSampler(
        OracleDenoiser(analytic),
        analytic.schedule,
        solver_config=ERAConfig(per_sample=False),
        batch_buckets=None,
    )
    ts = solo.submit(SampleRequest(batch=8, seq_len=6, nfe=10, seed=1))
    np.testing.assert_allclose(
        np.asarray(res.x0),
        np.asarray(solo.drain(params=None)[ts].x0),
        atol=1e-5,
    )


def test_shared_delta_off_mesh_accepts_any_batch(analytic):
    """dp=1 (no mesh): every batch is a dp multiple, nothing is rejected."""
    eng = BatchedSampler(
        OracleDenoiser(analytic),
        analytic.schedule,
        solver_config=ERAConfig(per_sample=False),
        batch_buckets=(8,),
    )
    t = eng.submit(SampleRequest(batch=3, seq_len=6, nfe=10, seed=0))
    assert eng.drain(params=None)[t].x0.shape == (3, 6, D_MODEL)


@pytest.mark.parametrize("solver", ["dpm_solver_pp2m"])
def test_non_era_mesh_drain_parity_with_single_device(mesh8, analytic, solver):
    """PR-4: every program (not just ERA) gets mesh-sharded fused drains —
    an 8-way mesh drain of a non-ERA solver matches the single-device
    engine, with the batch genuinely spread over the mesh."""
    meshed = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, mesh=mesh8
    )
    single = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=None
    )
    reqs = [(1, 3), (3, 4), (4, 5)]  # 8 rows: one full dp-rounded bucket
    tickets = {
        eng: [
            eng.submit(
                SampleRequest(batch=b, seq_len=6, nfe=8, solver=solver, seed=s)
            )
            for b, s in reqs
        ]
        for eng in (meshed, single)
    }
    res_m = meshed.drain(params=None)
    res_s = single.drain(params=None)
    for tm, ts in zip(tickets[meshed], tickets[single]):
        np.testing.assert_allclose(
            np.asarray(res_m[tm].x0), np.asarray(res_s[ts].x0), atol=1e-5
        )
    assert res_m[tickets[meshed][0]].padded_batch == 8
    full = meshed.submit(
        SampleRequest(batch=8, seq_len=6, nfe=8, solver=solver, seed=9)
    )
    x0 = meshed.drain(params=None)[full].x0
    assert len(x0.sharding.device_set) == 8  # sharded, not replicated


def test_mesh_drain_parity_with_single_device_engine():
    """8-device mesh drain == single-device drain within 1e-5, with batch
    buckets rounded to dp multiples and rows spread over all devices.

    Runs in-process when launched under
    XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI sharded
    job); otherwise re-runs itself in a flagged subprocess, so the parity
    wall holds in default single-device collection too."""
    if jax.device_count() >= 8:
        import _mesh_parity_main

        rec = _mesh_parity_main.run_parity()
    else:
        rec = run_mesh_subprocess("_mesh_parity_main.py")
    assert rec["devices"] >= 8  # make_sampler_mesh(8) caps bigger hosts
    assert rec["dp"] == 8
    assert rec["buckets"] == [8, 64]      # 1/8/64 dp-rounded
    assert rec["padded_batch"] == 8       # 6 mixed rows pad to the 8-bucket
    assert rec["padded_batch"] % rec["dp"] == 0
    assert rec["x0_devices"] == 8         # batch really spread over the mesh
    assert rec["max_diff"] <= 1e-5
