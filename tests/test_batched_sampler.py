"""Batched sampling engine: fused-step parity, compile-once-per-bucket, and
batch-of-N == N-independent-runs equivalence (per-sample ERS on)."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ERAConfig, get_solver
from repro.kernels import ops
from repro.serving import BatchedSampler, SampleRequest, fused_path_ok

D_MODEL = 8


class OracleDenoiser:
    """DiffusionLM-shaped wrapper around the analytic eps oracle, so engine
    tests are exact and fast (no network params)."""

    def __init__(self, analytic):
        self.analytic = analytic
        self.config = types.SimpleNamespace(d_model=D_MODEL)

    def eps_fn(self, params):
        return self.analytic.eps


@pytest.fixture()
def engine(analytic):
    return BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=(2, 4, 8)
    )


# ---------------------------------------------------------------------------
# fused default path numerics (acceptance: <= 1e-5 in f32, interpret mode)
# ---------------------------------------------------------------------------


def test_fused_step_parity_within_1e5():
    for shape in ((4, 96), (2, 8, 8), (130,)):
        for k in (3, 4, 6):
            err = ops.fused_step_parity(shape=shape, k=k)
            assert err <= 1e-5, (shape, k, err)


def test_fused_path_ok_gate():
    assert fused_path_ok()


# ---------------------------------------------------------------------------
# batched engine semantics
# ---------------------------------------------------------------------------


def test_submit_drain_shapes_and_metadata(engine, analytic):
    t1 = engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, seed=1))
    t2 = engine.submit(SampleRequest(batch=3, seq_len=6, nfe=8, seed=2))
    assert engine.pending == 2
    results = engine.drain(params=None)
    assert engine.pending == 0
    assert set(results) == {t1, t2}
    assert results[t1].x0.shape == (1, 6, D_MODEL)
    assert results[t2].x0.shape == (3, 6, D_MODEL)
    # 1 + 3 samples pad to the 4-bucket, fused into one batch
    assert results[t1].padded_batch == 4
    assert results[t1].batch_wall_s == results[t2].batch_wall_s
    assert results[t1].latency_s >= results[t1].batch_wall_s
    for res in results.values():
        assert not bool(jnp.any(jnp.isnan(res.x0)))
        assert "delta_eps_history" in res.aux


def test_batch_of_n_equals_independent_runs(engine, analytic):
    """Co-batched requests (per-sample ERS) match solo ERA-Solver runs."""
    seeds = [3, 4, 5]
    tickets = {
        s: engine.submit(SampleRequest(batch=1, seq_len=6, nfe=10, seed=s))
        for s in seeds
    }
    results = engine.drain(params=None)
    cfg = ERAConfig(nfe=10, per_sample=True)
    for s in seeds:
        x_init = jax.random.normal(
            jax.random.PRNGKey(s), (1, 6, D_MODEL), jnp.float32
        )
        solo = get_solver("era")(analytic.eps, x_init, analytic.schedule, cfg)
        np.testing.assert_allclose(
            np.asarray(results[tickets[s]].x0),
            np.asarray(solo.x0),
            atol=1e-5,
        )


def test_compile_once_per_bucket(engine):
    """Fluctuating request sizes within one bucket reuse one XLA program."""
    for seed, batch in enumerate((1, 2, 1, 2, 1)):
        engine.submit(SampleRequest(batch=batch, seq_len=6, nfe=8, seed=seed))
        engine.drain(params=None)
    cache = engine.compile_cache()
    assert len(cache) == 1  # batches 1 and 2 share the 2-bucket
    (runner,) = cache.values()
    assert runner._cache_size() == 1  # jit traced/compiled exactly once


def test_distinct_buckets_compile_separately(engine):
    engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, seed=0))
    engine.submit(SampleRequest(batch=1, seq_len=4, nfe=8, seed=1))
    engine.submit(SampleRequest(batch=1, seq_len=6, nfe=12, seed=2))
    res = engine.drain(params=None)
    assert len(res) == 3
    assert len(engine.compile_cache()) == 3  # (seq 6, 8) / (seq 4, 8) / (seq 6, 12)


def test_oversize_request_chunks_to_max_bucket(engine):
    big = engine.submit(SampleRequest(batch=5, seq_len=6, nfe=8, seed=0))
    small = engine.submit(SampleRequest(batch=2, seq_len=6, nfe=8, seed=1))
    res = engine.drain(params=None)
    assert res[big].x0.shape == (5, 6, D_MODEL)
    assert res[small].x0.shape == (2, 6, D_MODEL)


def test_shared_delta_config_not_fused(analytic):
    """Paper-default (shared delta_eps) configs couple the batch through one
    global error norm, so the engine must serve them unfused and unpadded —
    each request's result matches a solo run of exactly that request."""
    eng = BatchedSampler(
        OracleDenoiser(analytic),
        analytic.schedule,
        solver_config=ERAConfig(per_sample=False),
        batch_buckets=(8,),
    )
    t1 = eng.submit(SampleRequest(batch=2, seq_len=6, nfe=10, seed=11))
    t2 = eng.submit(SampleRequest(batch=1, seq_len=6, nfe=10, seed=12))
    results = eng.drain(params=None)
    assert results[t1].padded_batch == 2  # exact size, no pad, no fusion
    assert results[t2].padded_batch == 1
    for seed, ticket, batch in ((11, t1, 2), (12, t2, 1)):
        x_init = jax.random.normal(
            jax.random.PRNGKey(seed), (batch, 6, D_MODEL), jnp.float32
        )
        solo = get_solver("era")(
            analytic.eps, x_init, analytic.schedule, ERAConfig(nfe=10)
        )
        np.testing.assert_allclose(
            np.asarray(results[ticket].x0), np.asarray(solo.x0), atol=1e-5
        )


def test_padding_rows_do_not_leak(engine, analytic):
    """A request fused with pad rows equals the same request run alone."""
    t = engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, seed=7))
    padded = engine.drain(params=None)[t]
    assert padded.padded_batch == 2
    solo_engine = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=None
    )
    t2 = solo_engine.submit(SampleRequest(batch=1, seq_len=6, nfe=8, seed=7))
    solo = solo_engine.drain(params=None)[t2]
    assert solo.padded_batch == 1
    np.testing.assert_allclose(
        np.asarray(padded.x0), np.asarray(solo.x0), atol=1e-5
    )
