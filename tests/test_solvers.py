"""Solver behaviour on the analytic diffusion (exact eps oracle) —
convergence, budget accounting, the paper's error-robustness claims, and
the cross-path parity wall: every registry solver's scan program is
bit-identical to the pre-refactor eager sample (`tests/_legacy_solvers.py`)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _legacy_solvers
from repro.core import (
    ERAConfig,
    default_config,
    get_program,
    get_solver,
    solver_names,
)


def rmse(a, b):
    return float(jnp.sqrt(jnp.mean((a - b) ** 2)))


@pytest.mark.parametrize("name", solver_names())
def test_all_solvers_run_and_converge(name, analytic, xT, reference_x0):
    cfg = default_config(name, nfe=20)
    out = get_solver(name)(analytic.eps, xT, analytic.schedule, cfg)
    assert out.x0.shape == xT.shape
    assert not bool(jnp.any(jnp.isnan(out.x0)))
    assert rmse(out.x0, reference_x0) < 0.12, name


@pytest.mark.parametrize("name", ["ddim", "explicit_adams", "era"])
def test_error_decreases_with_nfe(name, analytic, xT, reference_x0):
    errs = [
        rmse(
            get_solver(name)(
                analytic.eps, xT, analytic.schedule, default_config(name, nfe=n)
            ).x0,
            reference_x0,
        )
        for n in (5, 10, 40)
    ]
    assert errs[2] < errs[0]


def test_high_order_beats_ddim(analytic, xT, reference_x0):
    e = {}
    for name in ("ddim", "era", "explicit_adams"):
        out = get_solver(name)(
            analytic.eps, xT, analytic.schedule, default_config(name, nfe=10)
        )
        e[name] = rmse(out.x0, reference_x0)
    assert e["era"] < e["ddim"] / 5
    assert e["explicit_adams"] < e["ddim"]


def test_nfe_budget_exact(analytic, xT):
    """1-eval-per-step solvers report exactly `nfe`; PECE reports 2/step."""
    for name in ("ddim", "explicit_adams", "era", "dpm_solver_fast"):
        out = get_solver(name)(
            analytic.eps, xT, analytic.schedule, default_config(name, nfe=8)
        )
        assert int(out.nfe) == 8, name
    out = get_solver("implicit_adams_pece")(
        analytic.eps, xT, analytic.schedule, default_config("implicit_adams_pece", nfe=8)
    )
    assert int(out.nfe) == 7  # 4 steps x 2 evals, final-step eval skipped


def test_era_fused_kernel_path_matches(analytic, xT):
    """The fused Pallas step (the default) tracks the pure-jnp path."""
    assert ERAConfig().use_fused_update  # fused is the default
    plain = get_solver("era")(
        analytic.eps, xT, analytic.schedule,
        ERAConfig(nfe=10, k=4, use_fused_update=False),
    )
    fused = get_solver("era")(
        analytic.eps, xT, analytic.schedule,
        ERAConfig(nfe=10, k=4, use_fused_update=True),
    )
    np.testing.assert_allclose(
        np.asarray(plain.x0), np.asarray(fused.x0), atol=2e-5
    )


def test_era_fused_per_sample_matches(analytic, xT):
    """Per-sample ERS: vmapped fused kernel == pure-jnp per-sample path."""
    cfg = ERAConfig(nfe=12, k=4, per_sample=True)
    fused = get_solver("era")(analytic.eps, xT, analytic.schedule, cfg)
    plain = get_solver("era")(
        analytic.eps, xT, analytic.schedule,
        dataclasses.replace(cfg, use_fused_update=False),
    )
    np.testing.assert_allclose(
        np.asarray(plain.x0), np.asarray(fused.x0), atol=2e-5
    )


def test_delta_eps_detects_injected_error(analytic, xT):
    """The error measure (Eq. 15) detects estimation error at sampling
    time: injected noise lifts delta_eps an order of magnitude over the
    clean-oracle baseline (paper Fig. 3's diagnostic property)."""
    k = 4
    cfg = ERAConfig(nfe=20, k=k, error_norm="mean")
    clean = np.asarray(
        get_solver("era")(analytic.eps, xT, analytic.schedule, cfg)
        .aux["delta_eps_history"]
    )
    noisy = np.asarray(
        get_solver("era")(analytic.noisy(0.08), xT, analytic.schedule, cfg)
        .aux["delta_eps_history"]
    )
    assert noisy[k:-1].mean() > 5.0 * clean[k:-1].mean()


def test_ers_rescues_high_order(analytic, xT, reference_x0):
    """Paper Table 4: fixed selection diverges at k=6; ERS stays stable."""
    noisy = analytic.noisy(0.05)
    errs = {}
    for sel in ("fixed", "ers"):
        out = get_solver("era")(
            noisy, xT, analytic.schedule,
            ERAConfig(nfe=20, k=6, lam=5.0, selection=sel, error_norm="mean"),
        )
        errs[sel] = rmse(out.x0, reference_x0)
    assert errs["ers"] < errs["fixed"] / 2, errs


def test_const_power_ablation_runs(analytic, xT):
    out = get_solver("era")(
        analytic.eps, xT, analytic.schedule,
        ERAConfig(nfe=12, k=3, selection="const", const_power=2.0),
    )
    assert not bool(jnp.any(jnp.isnan(out.x0)))


def test_solver_under_jit(analytic, xT):
    cfg = ERAConfig(nfe=10, k=4)
    f = jax.jit(
        lambda x: get_solver("era")(analytic.eps, x, analytic.schedule, cfg).x0
    )
    out = f(xT)
    assert not bool(jnp.any(jnp.isnan(out)))


def test_trajectory_recording(analytic, xT):
    cfg = ERAConfig(nfe=8, k=3, return_trajectory=True)
    out = get_solver("era")(analytic.eps, xT, analytic.schedule, cfg)
    traj = out.aux["trajectory"]
    assert traj.shape == (9,) + xT.shape
    np.testing.assert_allclose(np.asarray(traj[-1]), np.asarray(out.x0), atol=1e-5)


def test_per_sample_ers_isolates_batch_noise(analytic, xT, reference_x0):
    """Beyond-paper: per-sample ERS — a noisy batch-mate must not degrade
    clean samples' selection (the paper's scalar delta_eps is shared)."""

    def hetero(x, t):
        key = jax.random.fold_in(
            jax.random.PRNGKey(7), (t * 1e6).astype(jnp.int32)
        )
        mag = 0.02 * (1.0 + 4.0 * jnp.exp(-6.0 * t))
        noise = mag * jax.random.normal(key, x.shape)
        b = x.shape[0]
        scale = jnp.where(jnp.arange(b) < b // 2, 1.0, 5.0)[:, None]
        return analytic.eps(x, t) + scale * noise

    def clean_rmse(cfg):
        out = get_solver("era")(hetero, xT, analytic.schedule, cfg)
        err = jnp.sqrt(jnp.mean((out.x0 - reference_x0) ** 2, axis=-1))
        return float(jnp.mean(err[: xT.shape[0] // 2]))

    shared = clean_rmse(ERAConfig(nfe=15, k=5, lam=2.0, error_norm="mean"))
    per_sample = clean_rmse(ERAConfig(nfe=15, k=5, lam=2.0, per_sample=True))
    assert per_sample < shared * 0.5, (per_sample, shared)


# ---------------------------------------------------------------------------
# cross-path parity wall: the PR-4 scan programs vs the pre-refactor loops
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def toy_dlm():
    """Seeded toy DiffusionLM denoiser (smoke config) — real learned-ish
    eps with a transformer inside, so the parity wall covers the serving
    model path, not just the analytic oracle."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.diffusion import DiffusionLM

    cfg = get_config("qwen2-1.5b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(jax.random.PRNGKey(3))
    return dlm.eps_fn(params), cfg.d_model


@pytest.mark.parametrize("name", solver_names())
def test_scan_program_bit_identical_to_legacy(name, analytic, xT):
    """The rewritten single-scan programs (ddim / explicit_adams / PECE /
    dpm++2m) reproduce the pre-refactor fori_loop samplers bit-for-bit;
    unrewritten solvers (era, singlestep DPM) trivially match themselves."""
    cfg = default_config(name, nfe=12)
    new = get_solver(name)(analytic.eps, xT, analytic.schedule, cfg)
    old = _legacy_solvers.legacy_sample(
        name, analytic.eps, xT, analytic.schedule, cfg
    )
    np.testing.assert_array_equal(np.asarray(new.x0), np.asarray(old.x0))
    assert int(new.nfe) == int(old.nfe)


@pytest.mark.parametrize("name", solver_names())
def test_scan_program_bit_identical_to_legacy_on_diffusion_lm(name, toy_dlm):
    eps_fn, d_model = toy_dlm
    from repro.core import linear_schedule

    sched = linear_schedule()
    cfg = default_config(name, nfe=6)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 8, d_model), jnp.float32)
    new = get_solver(name)(eps_fn, x, sched, cfg)
    old = _legacy_solvers.legacy_sample(name, eps_fn, x, sched, cfg)
    np.testing.assert_array_equal(np.asarray(new.x0), np.asarray(old.x0))


@pytest.mark.parametrize(
    "name", ["ddim", "explicit_adams", "implicit_adams_pece", "dpm_solver_pp2m"]
)
def test_rewritten_programs_match_legacy_under_jit(name, analytic, xT):
    """The same parity inside an outer jit (the serving engine's shape):
    buffers allocated outside, threaded through the program entry."""
    program = get_program(name)
    cfg = default_config(name, nfe=10)

    @jax.jit
    def run(x, *buffers):
        return program.sample_scan(
            analytic.eps, x, buffers, analytic.schedule, cfg
        ).x0

    buffers = program.alloc_buffers(xT, cfg)
    new = run(xT, *buffers)
    old = _legacy_solvers.legacy_sample(
        name, analytic.eps, xT, analytic.schedule, cfg
    )
    np.testing.assert_allclose(
        np.asarray(new), np.asarray(old.x0), atol=1e-6
    )


def test_rewritten_programs_trajectory_matches_x0(analytic, xT):
    """The scan programs' optional trajectory recording ends at x0 and has
    one entry per step plus the initial state."""
    for name in ("ddim", "explicit_adams", "implicit_adams_pece"):
        cfg = default_config(name, nfe=8, return_trajectory=True)
        out = get_solver(name)(analytic.eps, xT, analytic.schedule, cfg)
        steps = 4 if name == "implicit_adams_pece" else 8
        traj = out.aux["trajectory"]
        assert traj.shape == (steps + 1,) + xT.shape, name
        np.testing.assert_allclose(
            np.asarray(traj[-1]), np.asarray(out.x0), atol=1e-5
        )


def test_dpm_solver_pp2m_converges(analytic, xT, reference_x0):
    """DPM-Solver++(2M) (the paper's Appendix-E baseline): 1 NFE/step,
    2nd order, stable at tiny NFE where singlestep DPM-Solver collapses."""
    for nfe in (5, 10):
        out = get_solver("dpm_solver_pp2m")(
            analytic.eps, xT, analytic.schedule,
            default_config("dpm_solver_pp2m", nfe=nfe),
        )
        assert int(out.nfe) == nfe
        assert rmse(out.x0, reference_x0) < 0.05, nfe
