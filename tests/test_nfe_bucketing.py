"""Padding-invariance wall for mixed-NFE fusion (NFE bucketing).

The serving contract: with ``nfe_buckets`` configured, requests whose
``nfe`` differ fuse into one compiled batch — the scan runs to the
bucket's step count and each request row carries its own step budget and
its own exact-NFE time grid through a per-row :class:`StepMask` — and a
request drained at its exact NFE (a ladder whose bucket equals its nfe:
every step active) is **bit-identical** to the same request right-padded
to a coarser bucket and co-fused with mixed-NFE batch-mates.  What makes
the bitwise claim hold (not just "close"): every row's active prefix
gathers the very same per-row time grid floats in both runs, and a spent
row's update is an exact ``jnp.where`` freeze of its whole carry —
latents, Lagrange eps history, ERS selection state — never a re-derived
value (see ``program.step_active`` / each program's step-masked scan).

Also walled here: the compile count is bounded by the nfe-bucket ladder
(not by distinct nfes), over-ladder requests are rejected at submit with
an actionable message, solvers without a step-masked scan (and
non-fusable configs) fall back to exact-NFE grouping on the
``sampler_masked_fallback_total`` canary, wasted pad step-rows are counted
on ``sampler_nfe_padding_rows_total``, step-stacked aux is scoped back to
each request's own step count, ``padded_nfe`` is surfaced through results
and the info dict, and the mesh8 mixed-NFE drain matches.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from conftest import AnalyticGaussian, OracleDenoiser
from repro.core import ERAConfig, solver_names
from repro.serving import (
    AsyncBatchedSampler,
    BatchedSampler,
    SampleRequest,
    result_keys as K,
)

# module-level: the shim's `given` produces zero-arg tests, so no fixtures
ANALYTIC = AnalyticGaussian()

SEQ_BUCKETS = (4, 8)

# solvers with a step-masked scan (SolverProgram.supports_steps) fuse
# across NFEs; the rest group by exact NFE.  The completeness test below
# forces every future registry solver to be classified here — and thereby
# through the padding-invariance wall.
STEPPED_SOLVERS = (
    "ddim",
    "dpm_adaptive",
    "dpm_solver_pp2m",
    "era",
    "explicit_adams",
    "implicit_adams_pece",
)
UNSTEPPED_SOLVERS = ("dpm_solver_2", "dpm_solver_fast")


def _engine(nfe_buckets, mesh=None, **kw):
    return BatchedSampler(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        batch_buckets=(2, 4),
        seq_buckets=SEQ_BUCKETS,
        nfe_buckets=nfe_buckets,
        mesh=mesh,
        **kw,
    )


def _drain_one(engine, req, mates=()):
    ticket = engine.submit(req)
    for m in mates:
        engine.submit(m)
    return engine.drain(None)[ticket]


@settings(max_examples=2, deadline=None)
@given(
    st.integers(min_value=8, max_value=16),      # request nfe
    st.integers(min_value=1, max_value=8),       # request seq_len
    st.integers(min_value=0, max_value=10_000),  # request seed base
)
def test_nfe_padding_invariance_bitwise(nfe, seq0, seed0):
    """For every step-masked solver: a request drained at its exact NFE
    vs. right-padded to a coarser NFE bucket (co-fused with a batch-mate
    at a different nfe) yields bit-identical x0, per-sample delta_eps
    histories, and ERA basis selections."""
    for solver in STEPPED_SOLVERS:
        req = SampleRequest(
            batch=1, seq_len=seq0, nfe=nfe, solver=solver, seed=seed0
        )
        # reference: exact-NFE drain — a ladder whose bucket == nfe, so
        # the step-masked scan runs with every step active
        ref = _drain_one(_engine((nfe, nfe + 40)), req)
        assert ref.padded_nfe == nfe
        # padded: a coarser ladder right-pads the request's steps, fused
        # with a mate at a different nfe (same bucket) so the chunk is a
        # genuinely mixed-NFE batch.  The mate keeps both runs on the same
        # batch bucket — the bitwise contract holds between step-masked
        # runs of the same compiled batch shape (different batch shapes
        # may vectorize the schedule transcendentals differently)
        mate = SampleRequest(
            batch=1, seq_len=seq0, nfe=nfe + 3, solver=solver,
            seed=seed0 + 1,
        )
        got = _drain_one(_engine((nfe + 7, nfe + 40)), req, mates=(mate,))
        assert got.padded_nfe == nfe + 7
        assert got.info[K.PADDED_NFE] == nfe + 7
        np.testing.assert_array_equal(
            np.asarray(got.x0), np.asarray(ref.x0),
            err_msg=f"x0 diverged under NFE padding (solver={solver}, "
            f"nfe={nfe} -> bucket {got.padded_nfe}, seed={seed0})",
        )
        if solver == "era":
            np.testing.assert_array_equal(
                np.asarray(got.aux["ers_selection_history"]),
                np.asarray(ref.aux["ers_selection_history"]),
                err_msg=f"ERS basis selection flipped under NFE padding "
                f"(nfe={nfe} -> bucket {got.padded_nfe})",
            )
            np.testing.assert_array_equal(
                np.asarray(got.aux["delta_eps_history_per_sample"]),
                np.asarray(ref.aux["delta_eps_history_per_sample"]),
                err_msg="per-sample delta_eps diverged under NFE padding",
            )
        if solver == "dpm_adaptive":
            np.testing.assert_array_equal(
                np.asarray(got.aux["realized_nfe"]),
                np.asarray(ref.aux["realized_nfe"]),
                err_msg="adaptive realized NFE diverged under NFE padding",
            )


def test_every_registry_solver_is_classified():
    """Every registry solver is either step-masked (and walled by the
    invariance test above) or an explicit exact-NFE fallback — a new
    solver cannot ship unclassified."""
    assert set(STEPPED_SOLVERS) | set(UNSTEPPED_SOLVERS) == set(
        solver_names()
    )
    engine = _engine((8, 16))
    for s in STEPPED_SOLVERS:
        assert engine.executor.nfe_masked(s) is True, s
    for s in UNSTEPPED_SOLVERS:
        assert engine.executor.nfe_masked(s) is False, s


def test_unstepped_solver_falls_back_to_exact_nfe():
    """A solver without a step-masked scan groups by exact NFE on a
    laddered engine — bit-identical to the ladder-free engine — and its
    verdict is counted once on the fallback canary."""
    engine = _engine((12, 25))
    for solver in UNSTEPPED_SOLVERS:
        assert engine.executor.nfe_masked(solver) is False
        req = SampleRequest(
            batch=1, seq_len=5, nfe=10, solver=solver, seed=77
        )
        assert engine.executor.group_key(req) == (solver, 8, 10)
        got = _drain_one(engine, req)
        assert got.padded_nfe == 10  # exact, not a ladder bucket
        ref = _drain_one(_engine(None), req)
        np.testing.assert_array_equal(
            np.asarray(got.x0), np.asarray(ref.x0),
            err_msg=f"exact-NFE fallback diverged (solver={solver})",
        )
    counter = engine.executor.metrics.get("sampler_masked_fallback_total")
    assert counter.value(
        impl="nfe-bucketing", reason="program-no-steps"
    ) == len(UNSTEPPED_SOLVERS)
    # the verdict is cached per solver: re-asking does not re-count
    assert engine.executor.nfe_masked("dpm_solver_fast") is False
    assert counter.value(
        impl="nfe-bucketing", reason="program-no-steps"
    ) == len(UNSTEPPED_SOLVERS)


def test_shared_delta_era_falls_back_to_exact_nfe():
    """Shared-delta ERA (per_sample=False) cannot pad in steps any more
    than in rows: exact-NFE grouping, counted as non-fusable-config."""
    engine = BatchedSampler(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        solver_config=ERAConfig(nfe=6, k=3, per_sample=False),
        batch_buckets=(2, 4),
        nfe_buckets=(8, 16),
    )
    assert engine.executor.nfe_masked("era") is False
    counter = engine.executor.metrics.get("sampler_masked_fallback_total")
    assert counter.value(
        impl="nfe-bucketing", reason="non-fusable-config"
    ) == 1
    assert engine.executor.group_key(
        SampleRequest(batch=1, seq_len=5, nfe=6)
    ) == ("era", 5, 6)


def test_mixed_nfes_fuse_into_one_chunk_per_bucket():
    """Distinct nfes inside one bucket share a fused batch and one
    compiled program; the jit cache is keyed by the ladder."""
    engine = _engine((8, 12))
    reqs = [
        SampleRequest(batch=1, seq_len=4, nfe=n, seed=10 + i)
        for i, n in enumerate([5, 7, 8, 6])  # all bucket to 8
    ]
    tickets = [engine.submit(r) for r in reqs]
    results = engine.drain(None)
    for t in tickets:
        assert results[t].padded_nfe == 8
        assert results[t].padded_batch == 4  # one fused chunk of 4 rows
    keys = set(engine.compile_cache())
    assert len(keys) == 1
    (key,) = keys
    # (solver, cfg, batch, seq, dp, masked, stepped): the cfg's nfe is the
    # group's bucket and the program is the step-masked variant
    assert key[1].nfe == 8 and key[6] is True

    # a second wave spanning both buckets: cfg nfes stay on the ladder
    more = [
        SampleRequest(batch=1, seq_len=4, nfe=n, seed=50 + i)
        for i, n in enumerate([6, 9, 12, 10])
    ]
    tickets = [engine.submit(r) for r in more]
    results = engine.drain(None)
    assert {results[t].padded_nfe for t in tickets} == {8, 12}
    assert {k[1].nfe for k in engine.compile_cache()} <= {8, 12}
    compiled = len(engine.compile_cache())

    # a third wave of previously-unseen nfes that lands on the same
    # (batch bucket, nfe bucket) compositions compiles nothing new — the
    # cache is bounded by the ladder, not by distinct nfes
    third = [
        SampleRequest(batch=1, seq_len=4, nfe=n, seed=80 + i)
        for i, n in enumerate([4, 5, 7, 6, 11, 9, 10])
    ]
    tickets = [engine.submit(r) for r in third]
    engine.drain(None)
    assert len(engine.compile_cache()) == compiled


def test_nfe_above_ladder_rejected_at_submit():
    engine = _engine((8, 12))
    with pytest.raises(ValueError, match="exceeds the largest nfe bucket"):
        engine.submit(SampleRequest(batch=1, seq_len=4, nfe=13))
    # the async scheduler rejects at submit too (same validate path)
    sched = AsyncBatchedSampler(engine, params=None)
    with pytest.raises(ValueError, match="exceeds the largest nfe bucket"):
        sched.submit(SampleRequest(batch=1, seq_len=4, nfe=40))
    sched.stop()
    # engines without a ladder accept the same nfe
    _engine(None).submit(SampleRequest(batch=1, seq_len=4, nfe=13))


def test_nfe_padding_rows_counter_counts_wasted_step_rows():
    """``sampler_nfe_padding_rows_total`` counts request rows that ran
    with padded (inert) steps — the ladder-tuning signal — and stays
    silent for traffic landing exactly on a bucket."""
    engine = _engine((8,))
    engine.submit(SampleRequest(batch=1, seq_len=4, nfe=5, seed=1))
    engine.submit(SampleRequest(batch=2, seq_len=4, nfe=8, seed=2))
    engine.drain(None)
    counter = engine.executor.metrics.get("sampler_nfe_padding_rows_total")
    assert counter is not None
    # only the 5-NFE request's single row padded; the 8-NFE rows ran
    # exactly, and the batch pad row runs the full bucket grid by design
    assert counter.value(solver="era") == 1

    engine.submit(SampleRequest(batch=2, seq_len=4, nfe=8, seed=3))
    engine.drain(None)
    assert counter.value(solver="era") == 1  # fully-active drain: no-op


def test_step_stacked_aux_scoped_to_request_nfe():
    """Step-stacked aux (trajectory, ERS histories) drops the inert pad
    tail: a 5-NFE request fused into an 8-NFE bucket gets histories at
    its own step count, same as its unpadded run."""
    engine = BatchedSampler(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        solver_config=ERAConfig(per_sample=True, return_trajectory=True),
        batch_buckets=(4,),
        seq_buckets=SEQ_BUCKETS,
        nfe_buckets=(8,),
    )
    ta = engine.submit(SampleRequest(batch=1, seq_len=3, nfe=5, seed=0))
    tb = engine.submit(SampleRequest(batch=2, seq_len=7, nfe=8, seed=1))
    results = engine.drain(None)
    # trajectory: x_init + one entry per *own* step, not per bucket step
    assert results[ta].aux["trajectory"].shape == (
        6, 1, 3, OracleDenoiser.D_MODEL
    )
    assert results[tb].aux["trajectory"].shape == (
        9, 2, 7, OracleDenoiser.D_MODEL
    )
    assert results[ta].aux["ers_selection_history"].shape[0] == 5
    assert results[ta].aux["delta_eps_history_per_sample"].shape[0] == 5
    assert results[tb].aux["ers_selection_history"].shape[0] == 8


def test_mesh_mixed_nfe_drain_parity(mesh8):
    """Mixed-NFE fused drains on the 8-device mesh: bit-identical to the
    mesh exact-NFE-bucket drains, and matching the single-device bucketed
    run to float tolerance (the established mesh-parity bar)."""
    reqs = [
        SampleRequest(batch=1, seq_len=5, nfe=n, seed=900 + i)
        for i, n in enumerate([6, 10, 13])
    ]
    ladder = (16,)
    mesh_engine = _engine(ladder, mesh=mesh8)
    tickets = [mesh_engine.submit(r) for r in reqs]
    fused = mesh_engine.drain(None)
    single = _engine(ladder)
    stickets = [single.submit(r) for r in reqs]
    sres = single.drain(None)
    for ticket, sticket, req in zip(tickets, stickets, reqs):
        # mesh reference: same request drained at its exact NFE bucket
        ref = _drain_one(_engine((req.nfe, 16), mesh=mesh8), req)
        np.testing.assert_array_equal(
            np.asarray(fused[ticket].x0), np.asarray(ref.x0),
            err_msg=f"mesh NFE-padded vs mesh exact-bucket diverged "
            f"(nfe={req.nfe})",
        )
        np.testing.assert_allclose(
            np.asarray(fused[ticket].x0), np.asarray(sres[sticket].x0),
            atol=1e-5,
            err_msg=f"mesh vs single-device bucketed diverged "
            f"(nfe={req.nfe})",
        )
