"""Info-dict key audit: the documented constants in
`repro.serving.result_keys` are the ONLY spelling of the engine's telemetry
and stats keys.

The grep wall scans every serving-layer and benchmark source file for
quoted literals of the documented keys — a stringly-typed duplicate
(`info["wall_s"]` instead of `info[K.WALL_S]`) is a latent rename hazard
and fails here by filename:line.
"""

import re
from pathlib import Path

from repro.core import ERAConfig, get_solver, linear_schedule

from conftest import AnalyticGaussian, OracleDenoiser
from repro.serving import SampleRequest, SamplerService, result_keys as K

ROOT = Path(__file__).resolve().parent.parent
SCANNED_DIRS = ("src/repro/serving", "benchmarks")
# the one place the literals are allowed to exist
DEFINING_FILE = ROOT / "src/repro/serving/result_keys.py"

_WALL = re.compile(
    r"""["'](%s)["']""" % "|".join(sorted(K.INFO_KEYS + K.STATS_KEYS
                                          + K.AUX_KEYS, key=len, reverse=True))
)


def test_no_stringly_typed_key_duplicates():
    offenders = []
    for d in SCANNED_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            if path == DEFINING_FILE:
                continue
            for n, line in enumerate(
                path.read_text().splitlines(), start=1
            ):
                m = _WALL.search(line)
                if m:
                    offenders.append(
                        f"{path.relative_to(ROOT)}:{n}: "
                        f"stringly-typed key {m.group(0)} — use "
                        f"result_keys.{m.group(1).upper()}"
                    )
    assert not offenders, "\n".join(offenders)


def test_constants_cover_info_dict():
    """Every key a real SampleResult.info exposes is documented: INFO_KEYS
    for the engine telemetry, AUX_KEYS for the solver diagnostics — no
    undocumented key can appear without failing here."""
    analytic = AnalyticGaussian()
    svc = SamplerService(
        OracleDenoiser(analytic),
        linear_schedule(),
        solver_config=ERAConfig(nfe=6, k=3, per_sample=True),
    )
    info = svc.sample(None, SampleRequest(batch=1, seq_len=4, nfe=6)).info
    documented = set(K.INFO_KEYS) | set(K.AUX_KEYS)
    assert set(K.INFO_KEYS) <= set(info)
    undocumented = set(info) - documented
    assert not undocumented, (
        f"SampleResult.info exposes undocumented keys {sorted(undocumented)} "
        f"— add them to repro.serving.result_keys"
    )


def test_aux_keys_match_solver_output():
    """The documented AUX_KEYS spellings are the ones the solver actually
    emits (guards against constants drifting from core)."""
    analytic = AnalyticGaussian()
    import jax

    out = get_solver("era")(
        analytic.eps,
        jax.random.normal(jax.random.PRNGKey(0), (2, 4)),
        analytic.schedule,
        ERAConfig(nfe=6, k=3, per_sample=True),
    )
    assert K.DELTA_EPS_HISTORY in out.aux
    assert K.ERS_SELECTION_HISTORY in out.aux
