"""End-to-end behaviour of the paper's system: train a denoiser, sample with
every solver, and verify the paper's headline orderings hold on a model with
*real* (learned, imperfect) noise estimates — the regime ERA-Solver targets.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ERAConfig, default_config, get_solver, linear_schedule
from repro.data import DataConfig, GaussianMixtureLatents
from repro.models import build_model
from repro.models.diffusion import DiffusionLM
from repro.training import OptimizerConfig, make_diffusion_train_step, train


@pytest.fixture(scope="module")
def trained():
    """A small diffusion-LM trained briefly on a known mixture."""
    cfg = get_config("llama3.2-1b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(jax.random.PRNGKey(0))
    sched = linear_schedule()
    dc = DataConfig(vocab_size=1, seq_len=8, batch_size=16, kind="diffusion",
                    d_model=cfg.d_model, num_modes=2, seed=3)
    data = GaussianMixtureLatents(dc)
    step = make_diffusion_train_step(
        dlm, OptimizerConfig(lr=2e-3, warmup_steps=5, total_steps=80), sched
    )
    res = train(step, params, data.batches(), 80, log_every=1000,
                print_fn=lambda s: None)
    return dlm, res.params, sched, data, cfg


def _sample(trained, solver, nfe, **kw):
    dlm, params, sched, data, cfg = trained
    xT = jax.random.normal(jax.random.PRNGKey(7), (32, 8, cfg.d_model))
    conf = (
        ERAConfig(nfe=nfe, **kw) if solver == "era"
        else default_config(solver, nfe=nfe)
    )
    return get_solver(solver)(dlm.eps_fn(params), xT, sched, conf).x0


def _ref(trained):
    """Fine-grained DDIM on the same trained model = solver ground truth."""
    dlm, params, sched, data, cfg = trained
    xT = jax.random.normal(jax.random.PRNGKey(7), (32, 8, cfg.d_model))
    return get_solver("ddim")(
        dlm.eps_fn(params), xT, sched, default_config("ddim", nfe=400)
    ).x0


def test_all_solvers_finite_on_trained_model(trained):
    for solver in ("ddim", "explicit_adams", "dpm_solver_fast", "era"):
        x0 = _sample(trained, solver, 10, **({"k": 3} if solver == "era" else {}))
        assert not bool(jnp.any(jnp.isnan(x0))), solver


def test_era_beats_high_order_peers_at_low_nfe(trained):
    """Paper Tables 1-3 ordering on learned noise estimates: at NFE=10,
    ERA beats the other high-order solvers (implicit-Adams PECE at matched
    cost, DPM-Solver-fast) and stays within range of DDIM on a metric that
    structurally favors DDIM (the reference is a fine DDIM run —
    EXPERIMENTS.md discusses the bias).  This briefly-trained model's noise
    error is large and iid-like (see test_high_order_regime_dependence), so
    the error-robust order here is k=2; higher k only pays off with the
    accurate estimates of a fully trained model."""
    ref = _ref(trained)
    err = {}
    for solver in ("ddim", "implicit_adams_pece", "dpm_solver_fast", "era"):
        x0 = _sample(trained, solver, 10, **({"k": 2} if solver == "era" else {}))
        err[solver] = float(jnp.sqrt(jnp.mean((x0 - ref) ** 2)))
    assert err["era"] < err["implicit_adams_pece"], err
    assert err["era"] < err["dpm_solver_fast"], err
    assert err["era"] < 1.6 * err["ddim"], err


def test_high_order_regime_dependence(trained):
    """Interpolation-order stability on a real trained model: k=6 degrades
    badly for BOTH selection strategies here (this under-trained model's
    error is iid-like, the regime where EXPERIMENTS.md shows ERS cannot
    help — its advantage needs the paper's structured, t-correlated error,
    reproduced in test_solvers.py::test_ers_rescues_high_order).  The
    production-relevant assertion: the paper's recommended low orders stay
    an order of magnitude more accurate than k=6."""
    ref = _ref(trained)

    def err(k, sel):
        x0 = _sample(trained, "era", 20, k=k, lam=5.0, selection=sel,
                     error_norm="mean")
        return float(jnp.sqrt(jnp.mean((x0 - ref) ** 2)))

    e3 = err(3, "ers")
    e6_fixed = err(6, "fixed")
    e6_ers = err(6, "ers")
    assert np.isfinite(e6_ers) and np.isfinite(e6_fixed)
    assert e3 * 5 < min(e6_fixed, e6_ers), (e3, e6_fixed, e6_ers)
