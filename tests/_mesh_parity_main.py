"""Mesh-vs-single-device drain parity check for the batched sampling engine.

Run as a script under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(prints one JSON record on stdout), or import :func:`run_parity` from a test
process that already has >= 8 devices.  Either way it drains the same mixed
request stream through an 8-way mesh-sharded engine and a plain single-
device engine and reports the max output difference plus placement facts.
"""

from __future__ import annotations

import json


def run_parity(seq_len: int = 6, nfe: int = 10) -> dict:
    import jax
    import numpy as np

    from conftest import AnalyticGaussian, OracleDenoiser
    from repro.launch.mesh import make_sampler_mesh
    from repro.serving import BatchedSampler, SampleRequest

    analytic = AnalyticGaussian()
    mesh = make_sampler_mesh(8)
    meshed = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, mesh=mesh
    )
    single = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=None
    )

    # mixed sizes: 1 + 3 + 2 = 6 rows, padding to the dp-rounded 8-bucket
    reqs = [(1, 3), (3, 4), (2, 5)]
    tickets = {
        eng: [
            eng.submit(SampleRequest(batch=b, seq_len=seq_len, nfe=nfe, seed=s))
            for b, s in reqs
        ]
        for eng in (meshed, single)
    }
    res_m = meshed.drain(params=None)
    res_s = single.drain(params=None)

    max_diff = 0.0
    for tm, ts in zip(tickets[meshed], tickets[single]):
        diff = np.max(
            np.abs(np.asarray(res_m[tm].x0) - np.asarray(res_s[ts].x0))
        )
        max_diff = max(max_diff, float(diff))

    # a full-bucket request, to read the placement off an unsliced result
    tm8 = meshed.submit(SampleRequest(batch=8, seq_len=seq_len, nfe=nfe, seed=9))
    ts8 = single.submit(SampleRequest(batch=8, seq_len=seq_len, nfe=nfe, seed=9))
    full_m = meshed.drain(params=None)[tm8]
    full_s = single.drain(params=None)[ts8]
    max_diff = max(
        max_diff,
        float(np.max(np.abs(np.asarray(full_m.x0) - np.asarray(full_s.x0)))),
    )
    return {
        "devices": jax.device_count(),
        "dp": meshed.dp,
        "buckets": list(meshed.batch_buckets),
        "padded_batch": res_m[tickets[meshed][0]].padded_batch,
        "x0_devices": len(full_m.x0.sharding.device_set),
        "max_diff": max_diff,
    }


if __name__ == "__main__":
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    print(json.dumps(run_parity()))
