import jax
import jax.numpy as jnp
import pytest

from repro.core import linear_schedule


class AnalyticGaussian:
    """Gaussian-data diffusion with closed-form optimal eps predictor.

    x0 ~ N(mu, s^2 I)  =>  eps*(x,t) = (x - alpha(t) mu) sigma(t) /
                                        (alpha^2 s^2 + sigma^2)
    """

    def __init__(self, mu=1.5, s=0.5, schedule=None):
        self.mu, self.s = mu, s
        self.schedule = schedule or linear_schedule()

    def eps(self, x, t):
        a = self.schedule.alpha(t)
        sg = self.schedule.sigma(t)
        return (x - a * self.mu) * sg / (a * a * self.s**2 + sg * sg)

    def noisy(self, scale, seed=42, late_boost=4.0):
        """eps* + noise whose magnitude grows as t->0 (paper Fig. 1)."""

        def fn(x, t):
            key = jax.random.fold_in(
                jax.random.PRNGKey(seed), (t * 1e6).astype(jnp.int32)
            )
            mag = scale * (1.0 + late_boost * jnp.exp(-6.0 * t))
            return self.eps(x, t) + mag * jax.random.normal(key, x.shape)

        return fn


@pytest.fixture(scope="session")
def analytic():
    return AnalyticGaussian()


@pytest.fixture(scope="session")
def xT():
    return jax.random.normal(jax.random.PRNGKey(0), (64, 8))


@pytest.fixture(scope="session")
def reference_x0(analytic, xT):
    from repro.core import default_config, get_solver

    return get_solver("ddim")(
        analytic.eps, xT, analytic.schedule, default_config("ddim", nfe=2000)
    ).x0
