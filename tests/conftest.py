import json
import os
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import pytest

from repro.core import linear_schedule

MESH_DEVICES = 8
MESH_XLA_FLAG = f"--xla_force_host_platform_device_count={MESH_DEVICES}"


class AnalyticGaussian:
    """Gaussian-data diffusion with closed-form optimal eps predictor.

    x0 ~ N(mu, s^2 I)  =>  eps*(x,t) = (x - alpha(t) mu) sigma(t) /
                                        (alpha^2 s^2 + sigma^2)
    """

    def __init__(self, mu=1.5, s=0.5, schedule=None):
        self.mu, self.s = mu, s
        self.schedule = schedule or linear_schedule()

    def eps(self, x, t):
        a = self.schedule.alpha(t)
        sg = self.schedule.sigma(t)
        return (x - a * self.mu) * sg / (a * a * self.s**2 + sg * sg)

    def noisy(self, scale, seed=42, late_boost=4.0):
        """eps* + noise whose magnitude grows as t->0 (paper Fig. 1)."""

        def fn(x, t):
            key = jax.random.fold_in(
                jax.random.PRNGKey(seed), (t * 1e6).astype(jnp.int32)
            )
            mag = scale * (1.0 + late_boost * jnp.exp(-6.0 * t))
            return self.eps(x, t) + mag * jax.random.normal(key, x.shape)

        return fn


class OracleDenoiser:
    """DiffusionLM-shaped wrapper around the analytic eps oracle, so engine
    tests are exact and fast (no network params).

    The oracle is positionwise (no cross-position mixing at all), so
    length masking is trivially supported: pad positions cannot influence
    valid ones, and the solver-side masked ERS norms do the rest.  The
    ``lengths`` argument is therefore accepted and ignored."""

    D_MODEL = 8
    supports_length_masking = True

    def __init__(self, analytic):
        self.analytic = analytic
        self.config = types.SimpleNamespace(d_model=self.D_MODEL)

    def eps_fn(self, params, lengths=None):
        return self.analytic.eps


@pytest.fixture(scope="session")
def mesh8():
    """8-virtual-CPU-device ("data",) mesh for sharded serving tests.

    Env guard: only materializes when the process was launched with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI sharded
    job does).  Single-device runs skip these cases — the same mesh parity
    is still covered there through the ``run_mesh_subprocess`` tests, which
    re-run the check in a flagged child process.
    """
    if jax.device_count() < MESH_DEVICES:
        pytest.skip(
            f"needs >= {MESH_DEVICES} devices; launch pytest with "
            f"XLA_FLAGS={MESH_XLA_FLAG}"
        )
    from repro.launch.mesh import make_sampler_mesh

    return make_sampler_mesh(MESH_DEVICES)


def run_mesh_subprocess(script: str, timeout: int = 600) -> dict:
    """Run a tests/ script under the 8-virtual-device XLA flag; parse the
    JSON record it prints on its last stdout line."""
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(tests_dir)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + MESH_XLA_FLAG).strip()
    # the virtual-device flag only multiplies CPU-platform devices; pin the
    # child to CPU so a GPU/TPU jax install still gets an 8-device mesh
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, os.path.join(tests_dir, script)],
        capture_output=True, text=True, timeout=timeout, cwd=root, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.fixture(scope="session")
def analytic():
    return AnalyticGaussian()


@pytest.fixture(scope="session")
def xT():
    return jax.random.normal(jax.random.PRNGKey(0), (64, 8))


@pytest.fixture(scope="session")
def reference_x0(analytic, xT):
    from repro.core import default_config, get_solver

    return get_solver("ddim")(
        analytic.eps, xT, analytic.schedule, default_config("ddim", nfe=2000)
    ).x0
