"""Scheduler wall for the continuous-batching AsyncBatchedSampler: policy
logic under a fake clock (no real sleeps), liveness (a lone request never
starves), thread-safe submission (no lost or duplicated tickets under
concurrent submit stress), clean shutdown with in-flight work, and chunk-
scoped failure isolation."""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import OracleDenoiser
from repro.serving import (
    AsyncBatchedSampler,
    BatchedSampler,
    SampleRequest,
    SchedulerPolicy,
    result_keys as K,
)

D_MODEL = OracleDenoiser.D_MODEL


def make_engine(analytic, buckets=(2, 4, 8)):
    return BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=buckets
    )


def req(seed, seq_len=6, nfe=8, batch=1):
    return SampleRequest(batch=batch, seq_len=seq_len, nfe=nfe, seed=seed)


# ---------------------------------------------------------------------------
# policy logic (pure, no engine)
# ---------------------------------------------------------------------------


def test_policy_target_rows():
    assert SchedulerPolicy(target_occupancy=1.0).target_rows(8) == 8
    assert SchedulerPolicy(target_occupancy=0.5).target_rows(8) == 4
    assert SchedulerPolicy(target_occupancy=0.01).target_rows(8) == 1
    # bucketless engines have no occupancy trigger: deadline only
    assert SchedulerPolicy().target_rows(None) is None


def test_policy_should_launch():
    p = SchedulerPolicy(max_wait_ms=10.0, target_occupancy=1.0)
    # below target and before the oldest request's deadline: hold
    assert not p.should_launch(now=1.0, oldest_t=1.0, rows=3, max_bucket=8)
    # occupancy reached: launch immediately, no matter the clock
    assert p.should_launch(now=1.0, oldest_t=1.0, rows=8, max_bucket=8)
    # deadline reached: launch whatever is there (deadline promotion)
    assert p.should_launch(now=1.0101, oldest_t=1.0, rows=1, max_bucket=8)
    # bucketless: only the deadline can trigger
    assert not p.should_launch(now=1.0, oldest_t=1.0, rows=100, max_bucket=None)
    assert p.should_launch(now=1.011, oldest_t=1.0, rows=1, max_bucket=None)


# ---------------------------------------------------------------------------
# scheduling decisions under a fake clock (no thread, no sleeps)
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.now = 100.0

    def __call__(self):
        return self.now


def test_deadline_launch_under_fake_clock(analytic):
    clock = FakeClock()
    sched = AsyncBatchedSampler(
        make_engine(analytic),
        params=None,
        policy=SchedulerPolicy(max_wait_ms=50.0),
        clock=clock,
    )
    fut = sched.submit(req(seed=1))
    # before the deadline and below occupancy: nothing may launch
    assert sched.drain_once(now=clock.now + 0.049) == 0
    assert not fut.done()
    # one tick past max_wait: the lone request is promoted and launches
    assert sched.drain_once(now=clock.now + 0.051) == 1
    assert fut.done()
    assert fut.result().x0.shape == (1, 6, D_MODEL)


def test_occupancy_launch_under_fake_clock(analytic):
    clock = FakeClock()
    sched = AsyncBatchedSampler(
        make_engine(analytic),
        params=None,
        policy=SchedulerPolicy(max_wait_ms=1e6, target_occupancy=0.5),
        clock=clock,
    )
    futs = [sched.submit(req(seed=s)) for s in range(3)]
    assert sched.drain_once(now=clock.now) == 0  # 3 rows < target 4
    futs.append(sched.submit(req(seed=3)))
    # target occupancy hit: launches with the deadline nowhere near
    assert sched.drain_once(now=clock.now) == 1
    assert all(f.done() for f in futs)
    assert futs[0].result().padded_batch == 4


def test_oldest_queue_served_first(analytic, monkeypatch):
    """Deadline promotion is oldest-arrival-first across shape queues."""
    clock = FakeClock()
    engine = make_engine(analytic)
    sched = AsyncBatchedSampler(
        engine,
        params=None,
        policy=SchedulerPolicy(max_wait_ms=10.0),
        clock=clock,
    )
    order = []
    orig = engine.executor.run_chunk

    def recording(params, seq_len, nfe, chunk, results, pad=True):
        order.append((seq_len, nfe))
        return orig(params, seq_len, nfe, chunk, results, pad=pad)

    monkeypatch.setattr(engine.executor, "run_chunk", recording)
    sched.submit(req(seed=0, seq_len=4))
    clock.now += 0.002
    sched.submit(req(seed=1, seq_len=6))
    clock.now += 0.002
    sched.submit(req(seed=2, seq_len=8))
    assert sched.drain_once(now=clock.now + 0.02) == 3
    assert order == [(4, 8), (6, 8), (8, 8)]


def test_launch_takes_at_most_one_max_bucket(analytic):
    """A deadline launch takes one largest-bucket's worth of rows; the
    remainder keeps its arrival time for the next launch."""
    clock = FakeClock()
    engine = make_engine(analytic, buckets=(4,))
    sched = AsyncBatchedSampler(
        engine,
        params=None,
        policy=SchedulerPolicy(max_wait_ms=10.0, target_occupancy=1e9),
        clock=clock,
    )
    futs = [sched.submit(req(seed=s)) for s in range(6)]
    assert sched.drain_once(now=clock.now + 0.02) == 1  # 4 of 6 rows
    assert sum(f.done() for f in futs) == 4
    assert sched.pending == 2
    assert sched.drain_once(now=clock.now + 0.04) == 1
    assert all(f.done() for f in futs)
    assert futs[0].result().padded_batch == 4


def test_chunk_failure_is_isolated(analytic, monkeypatch):
    """A failed launch fails only its own chunk's futures; the scheduler
    keeps serving other queues."""
    clock = FakeClock()
    engine = make_engine(analytic)
    sched = AsyncBatchedSampler(
        engine,
        params=None,
        policy=SchedulerPolicy(max_wait_ms=10.0),
        clock=clock,
    )
    orig = engine.executor.run_chunk

    def flaky(params, seq_len, nfe, chunk, results, pad=True):
        if seq_len == 4:
            raise RuntimeError("injected kernel failure")
        return orig(params, seq_len, nfe, chunk, results, pad=pad)

    monkeypatch.setattr(engine.executor, "run_chunk", flaky)
    bad = sched.submit(req(seed=0, seq_len=4))
    good = sched.submit(req(seed=1, seq_len=6))
    assert sched.drain_once(now=clock.now + 0.02) == 2
    with pytest.raises(RuntimeError, match="injected"):
        bad.result(timeout=0)
    assert not bool(jnp.any(jnp.isnan(good.result(timeout=0).x0)))


# ---------------------------------------------------------------------------
# liveness and thread safety (real drain thread)
# ---------------------------------------------------------------------------


def test_lone_request_is_not_starved(analytic):
    """max_wait_ms bounds a lone request's queue time: with no other traffic
    ever arriving, the future still resolves."""
    engine = make_engine(analytic)
    with AsyncBatchedSampler(
        engine, params=None, policy=SchedulerPolicy(max_wait_ms=5.0)
    ) as sched:
        fut = sched.submit(req(seed=42))
        res = fut.result(timeout=60)
    assert res.x0.shape == (1, 6, D_MODEL)
    assert sched.stats()[K.BATCHES] == 1


def test_concurrent_submit_stress_no_lost_or_duplicate_tickets(analytic):
    """N client threads submitting concurrently: every future resolves to
    its own request's result (seed-correct rows), and the scheduler's
    accounting sees exactly one ticket per submit."""
    engine = make_engine(analytic)
    n_threads, per_thread = 4, 6
    futures: dict[int, object] = {}
    lock = threading.Lock()

    with AsyncBatchedSampler(
        engine,
        params=None,
        policy=SchedulerPolicy(max_wait_ms=3.0, target_occupancy=0.5),
    ) as sched:

        def client(tid):
            for i in range(per_thread):
                seed = 1000 * tid + i
                fut = sched.submit(req(seed=seed))
                with lock:
                    futures[seed] = fut
                time.sleep(0.001 * (tid % 3))

        threads = [
            threading.Thread(target=client, args=(t,)) for t in range(n_threads)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        results = {s: f.result(timeout=60) for s, f in futures.items()}

    total = n_threads * per_thread
    assert len(results) == total
    stats = sched.stats()
    assert stats["submitted"] == total
    assert stats["rows"] == total  # no row lost, none launched twice
    # spot-check isolation: each future resolved to ITS request's samples
    # (bit-identical to a solo run of the same seed), not a batch-mate's
    solo = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=None
    )
    for seed in (0, 1003, 3005):
        ticket = solo.submit(req(seed=seed))
        ref = solo.drain(params=None)[ticket].x0
        np.testing.assert_array_equal(
            np.asarray(results[seed].x0), np.asarray(ref)
        )


def test_clean_shutdown_flushes_in_flight_work(analytic):
    """stop() with queued work resolves every outstanding future before
    returning, and later submits are rejected."""
    engine = make_engine(analytic)
    sched = AsyncBatchedSampler(
        engine,
        params=None,
        # deadline far away: the requests are still queued when stop() runs
        policy=SchedulerPolicy(max_wait_ms=60_000.0),
    ).start()
    futs = [sched.submit(req(seed=s)) for s in range(3)]
    sched.stop()
    assert all(f.done() for f in futs)
    for f in futs:
        assert f.result(timeout=0).x0.shape == (1, 6, D_MODEL)
    with pytest.raises(RuntimeError, match="stopped"):
        sched.submit(req(seed=9))


def test_stop_without_start_flushes(analytic):
    sched = AsyncBatchedSampler(make_engine(analytic), params=None)
    fut = sched.submit(req(seed=5))
    sched.stop()
    assert fut.result(timeout=0).x0.shape == (1, 6, D_MODEL)


def test_schedulers_are_one_shot(analytic):
    """start() after stop() fails loudly instead of spawning a thread that
    exits immediately and leaves submits mysteriously rejected."""
    sched = AsyncBatchedSampler(make_engine(analytic), params=None).start()
    sched.stop()
    with pytest.raises(RuntimeError, match="one-shot"):
        sched.start()
    sched.stop()  # idempotent: a second stop is a no-op, not a crash


def test_cancelled_future_does_not_kill_the_drain_thread(analytic):
    """A client that times out and cancels its future must not crash the
    launch that later tries to deliver to it — co-batched waiters and all
    later traffic still get results."""
    engine = make_engine(analytic)
    with AsyncBatchedSampler(
        engine,
        params=None,
        policy=SchedulerPolicy(max_wait_ms=20.0),
    ) as sched:
        gone = sched.submit(req(seed=0))
        assert gone.cancel()  # impatient client gives up pre-launch
        survivor = sched.submit(req(seed=1))
        assert survivor.result(timeout=60).x0.shape == (1, 6, D_MODEL)
        # the thread survived delivery-to-cancelled: it still serves
        later = sched.submit(req(seed=2))
        assert later.result(timeout=60).x0.shape == (1, 6, D_MODEL)


def test_engine_drain_tolerates_cancelled_future(analytic):
    engine = make_engine(analytic)
    t1, fut1 = engine.submit_with_future(req(seed=0))
    t2, fut2 = engine.submit_with_future(req(seed=1))
    assert fut1.cancel()
    results = engine.drain(params=None)
    assert set(results) == {t1, t2}  # the drain dict still carries both
    assert fut2.result(timeout=0).x0.shape == (1, 6, D_MODEL)


def test_submit_with_future_is_atomic_under_concurrent_drains(analytic):
    """A drain loop racing submitters can never orphan a result: the Future
    comes back from the same locked section that enqueues the ticket."""
    engine = make_engine(analytic)
    stop = threading.Event()

    def drain_loop():
        while not stop.is_set():
            engine.drain(params=None)

    th = threading.Thread(target=drain_loop)
    th.start()
    try:
        futs = [engine.submit_with_future(req(seed=s))[1] for s in range(8)]
        for f in futs:
            assert f.result(timeout=60).x0.shape == (1, 6, D_MODEL)
    finally:
        stop.set()
        th.join()


def test_sync_and_async_paths_share_compiled_buckets(analytic):
    """The scheduler reuses the sync engine's jit cache — same bucket, same
    program, zero extra compiles."""
    engine = make_engine(analytic, buckets=(4,))
    engine.submit(req(seed=0))
    engine.drain(params=None)
    cached = set(engine.compile_cache())
    with AsyncBatchedSampler(
        engine, params=None, policy=SchedulerPolicy(max_wait_ms=2.0)
    ) as sched:
        futs = [sched.submit(req(seed=s)) for s in range(3)]
        for f in futs:
            f.result(timeout=60)
    assert set(engine.compile_cache()) == cached
