import numpy as np

from repro.data import (
    DataConfig,
    GaussianMixtureLatents,
    TokenStream,
    frontend_features,
)


def test_token_stream_deterministic():
    dc = DataConfig(vocab_size=100, seq_len=16, batch_size=4, seed=7)
    a = next(TokenStream(dc).batches())["tokens"]
    b = next(TokenStream(dc).batches())["tokens"]
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 16) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 100


def test_token_stream_has_structure():
    """Markov structure: bigram entropy < unigram entropy."""
    dc = DataConfig(vocab_size=50, seq_len=256, batch_size=8, seed=0)
    toks = next(TokenStream(dc).batches())["tokens"]
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    # successors of a given token concentrate on few values
    concentrations = [
        len(set(v)) / len(v) for v in pairs.values() if len(v) >= 20
    ]
    assert np.mean(concentrations) < 0.8


def test_gaussian_mixture_moments():
    dc = DataConfig(vocab_size=1, seq_len=4, batch_size=2048,
                    kind="diffusion", d_model=16, num_modes=4, seed=1)
    g = GaussianMixtureLatents(dc)
    mu, var = g.moments()
    x = next(g.batches())["latents"].reshape(-1, 16)
    np.testing.assert_allclose(x.mean(0), mu, atol=0.15)
    np.testing.assert_allclose(x.var(0), var, atol=0.3)


def test_frontend_features_shape_and_range():
    rng = np.random.default_rng(0)
    f = frontend_features(rng, 2, 100, 64)
    assert f.shape == (2, 100, 64)
    assert np.all(np.abs(f) <= 2.0)
