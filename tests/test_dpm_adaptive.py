"""Correctness wall for the PID-controlled adaptive DPM-Solver program.

``dpm_adaptive`` runs the k-diffusion-style accept/reject loop as one
fixed-shape ``lax.scan`` (the request's ``nfe`` is an eval *budget*, 2 per
iteration) with per-row early exit, so it serves through the fused engine
— and through NFE bucketing — like any fixed-grid solver.  Walled here:

* convergence — a loose-tolerance run lands near the tight-tolerance
  reference on the analytic oracle and on a seeded toy DiffusionLM, with
  error shrinking as rtol tightens;
* determinism — for a fixed seed the realized step count and x0 are
  bit-identical under jit, across repeated jit calls, and vs. eager
  (the lambda endpoints are pinned behind an optimization barrier so
  XLA's constant folder cannot flip threshold comparisons);
* monotone control — tightening rtol or atol never *decreases* any
  row's realized NFE (more rejects, smaller steps);
* serveability — ``validate`` rejects unserveable tolerance configs at
  submit (not at drain, where they would poison co-batched neighbours),
  and a wire request through the unchanged front door returns 200 with
  the per-row realized NFE in ``info``.
"""

import jax
import numpy as np
import pytest

from conftest import AnalyticGaussian, OracleDenoiser
from repro.core import AdaptiveDPMConfig, get_solver
from repro.serving import (
    BatchedSampler,
    FrontDoorClient,
    SampleRequest,
    SchedulerPolicy,
    result_keys as K,
    serve_frontdoor,
)

ANALYTIC = AnalyticGaussian()

X_INIT = jax.random.normal(jax.random.PRNGKey(0), (2, 4))


def _run(cfg, x=X_INIT, eps=None):
    return get_solver("dpm_adaptive")(
        eps or ANALYTIC.eps, x, ANALYTIC.schedule, cfg
    )


def _tight_reference(x=X_INIT, eps=None):
    return _run(
        AdaptiveDPMConfig(nfe=300, rtol=1e-4, atol=1e-4), x=x, eps=eps
    )


def test_converges_to_tight_tolerance_reference_on_analytic_oracle():
    """The default-tolerance run lands near the tight-tolerance reference
    at a fraction of its budget, and tightening rtol closes the gap."""
    ref = _tight_reference()
    out = _run(AdaptiveDPMConfig(nfe=40))
    err = float(
        np.abs(np.asarray(out.x0) - np.asarray(ref.x0)).max()
    )
    assert err < 0.15, err  # observed ~8.6e-2 at rtol=0.05
    realized = np.asarray(out.aux["realized_nfe"])
    assert realized.shape == (2,)
    assert (realized <= 40).all() and (realized >= 2).all()
    assert (realized % 2 == 0).all()  # 2 evals per iteration, always
    # an order of magnitude tighter rtol: an order of magnitude closer
    out2 = _run(AdaptiveDPMConfig(nfe=200, rtol=0.005, atol=1e-4))
    err2 = float(
        np.abs(np.asarray(out2.x0) - np.asarray(ref.x0)).max()
    )
    assert err2 < 0.02, err2  # observed ~8.9e-3
    assert err2 < err


def test_converges_on_seeded_toy_diffusion_lm():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.diffusion import DiffusionLM

    cfg = get_config("llama3.2-1b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 5, cfg.d_model))

    def eps(xx, t):
        return dlm.eps(params, xx, t)

    ref = _tight_reference(x=x, eps=eps)
    out = _run(AdaptiveDPMConfig(nfe=200), x=x, eps=eps)
    err = float(np.abs(np.asarray(out.x0) - np.asarray(ref.x0)).max())
    assert err < 0.15, err  # observed ~5.5e-2 at rtol=0.05
    assert (
        np.asarray(out.aux["realized_nfe"])
        < np.asarray(ref.aux["realized_nfe"])
    ).all()


def test_realized_nfe_and_x0_deterministic_under_jit():
    """Fixed seed => fixed trajectory: repeated jit calls are bitwise
    identical, and the jitted run matches eager — realized step counts
    included (accept/reject must not flip under XLA's fusion choices)."""
    cfg = AdaptiveDPMConfig(nfe=40)

    @jax.jit
    def jf(xx):
        out = get_solver("dpm_adaptive")(
            ANALYTIC.eps, xx, ANALYTIC.schedule, cfg
        )
        return out.x0, out.aux["realized_nfe"]

    x1, r1 = jf(X_INIT)
    x2, r2 = jf(X_INIT)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    eager = _run(cfg)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(eager.x0))
    np.testing.assert_array_equal(
        np.asarray(r1), np.asarray(eager.aux["realized_nfe"])
    )


def test_tightening_tolerances_monotonically_raises_realized_nfe():
    """The controller honors rtol/atol monotonically: a tighter tolerance
    can only add rejects and shrink steps, so no row's realized NFE may
    drop.  (Budget is large enough that no run exhausts it.)"""
    prev = None
    for rtol in (0.5, 0.05, 0.005, 5e-4):
        out = _run(AdaptiveDPMConfig(nfe=200, rtol=rtol, atol=1e-4))
        realized = np.asarray(out.aux["realized_nfe"])
        assert (realized < 200).all()
        if prev is not None:
            assert (realized >= prev).all(), (rtol, realized, prev)
        prev = realized
    prev = None
    for atol in (0.5, 0.05, 0.005):
        out = _run(AdaptiveDPMConfig(nfe=200, rtol=1e-4, atol=atol))
        realized = np.asarray(out.aux["realized_nfe"])
        if prev is not None:
            assert (realized >= prev).all(), (atol, realized, prev)
        prev = realized


def _engine(config=None, **kw):
    kw.setdefault("batch_buckets", (2,))
    return BatchedSampler(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        solver="dpm_adaptive",
        solver_config=config,
        **kw,
    )


def test_validate_rejects_unserveable_configs_at_submit():
    req = SampleRequest(batch=1, seq_len=4, nfe=12)
    with pytest.raises(ValueError, match="budget must be >= 2"):
        _engine().submit(SampleRequest(batch=1, seq_len=4, nfe=1))
    with pytest.raises(ValueError, match="must be positive"):
        _engine(AdaptiveDPMConfig(rtol=-0.1)).submit(req)
    with pytest.raises(ValueError, match="below the serveable floor"):
        _engine(AdaptiveDPMConfig(rtol=1e-6, atol=1e-6)).submit(req)
    with pytest.raises(ValueError, match="limiter ceiling"):
        _engine(AdaptiveDPMConfig(accept_safety=2.8)).submit(req)
    # the floor is per-pair: one serveable tolerance is enough
    _engine(AdaptiveDPMConfig(rtol=1e-6, atol=0.01)).submit(req)


def test_adaptive_serves_mixed_budgets_under_nfe_bucketing():
    """Mixed adaptive budgets fuse into one bucketed chunk; every request
    reports its own realized NFE, capped by its own budget — not the
    bucket's."""
    engine = _engine(batch_buckets=(2, 4), nfe_buckets=(32,))
    ta = engine.submit(SampleRequest(batch=1, seq_len=4, nfe=10, seed=1))
    tb = engine.submit(SampleRequest(batch=2, seq_len=4, nfe=25, seed=2))
    results = engine.drain(None)
    assert results[ta].padded_nfe == 32
    for t, budget, rows in ((ta, 10, 1), (tb, 25, 2)):
        realized = np.asarray(results[t].aux["realized_nfe"])
        assert realized.shape == (rows,)
        assert (realized >= 2).all() and (realized <= budget).all()
        assert results[t].info[K.REALIZED_NFE] is results[t].aux[
            "realized_nfe"
        ]


def test_adaptive_serves_through_front_door_with_realized_nfe():
    """The acceptance check: an adaptive request through the unchanged
    front door returns 200 with the per-row realized NFE in ``info``,
    bit-identical to the in-process drain."""
    door = serve_frontdoor(
        _engine(nfe_buckets=(16,)), params=None,
        policy=SchedulerPolicy(max_wait_ms=5.0),
    )
    try:
        req = SampleRequest(batch=1, seq_len=4, nfe=12, seed=3)
        wire = FrontDoorClient(door.url, timeout=60).sample(req)
    finally:
        door.stop()
    realized = np.asarray(wire.info[K.REALIZED_NFE])
    assert realized.shape == (1,)
    assert 2 <= int(realized[0]) <= 12 and int(realized[0]) % 2 == 0
    assert wire.info[K.PADDED_NFE] == 16

    local_engine = _engine(nfe_buckets=(16,))
    t = local_engine.submit(req)
    local = local_engine.drain(None)[t]
    np.testing.assert_array_equal(np.asarray(local.x0), wire.x0)
    np.testing.assert_array_equal(
        np.asarray(local.aux["realized_nfe"]), realized
    )
