"""Cold-start contract: AOT grid warmup compiles without sampling, the
persistent compilation cache survives process boots, and ``/readyz``
gates traffic on warmup.

The wall these tests form around :meth:`BatchedSampler.warmup`:

* warmup populates the full program grid with **zero** sampling — no
  ``run_chunk`` calls, no drained batches — and serving after it is pure
  memory hits with output bit-identical to a cold engine's;
* a second process boot against the same ``compile_cache_dir`` loads its
  programs from disk instead of compiling them;
* the front door answers ``/readyz`` 503 (with progress) until warmup
  finishes, 200 after, and stays 503 with the error when warmup dies —
  while ``/healthz`` stays pure liveness throughout.
"""

import json
import os
import subprocess
import sys
import threading

import jax
import numpy as np
import pytest

from conftest import OracleDenoiser
from repro.serving import (
    BatchedSampler,
    EngineConfig,
    FrontDoorClient,
    SampleRequest,
    SchedulerPolicy,
    build_engine,
    serve_frontdoor,
    warmup_kwargs,
)

D_MODEL = OracleDenoiser.D_MODEL
BATCHES = (1, 2)
SEQS = (4, 8)


@pytest.fixture()
def engine(analytic):
    return BatchedSampler(
        OracleDenoiser(analytic),
        analytic.schedule,
        batch_buckets=BATCHES,
        seq_buckets=SEQS,
    )


def grid_requests(nfe=10):
    seed = iter(range(100))
    return [
        SampleRequest(batch=b, seq_len=s, nfe=nfe, seed=next(seed))
        for s in SEQS
        for b in BATCHES
    ]


# ---------------------------------------------------------------------------
# warmup compiles the grid without sampling
# ---------------------------------------------------------------------------


def test_warmup_compiles_grid_without_sampling(engine, monkeypatch):
    ex = engine.executor
    chunks = []
    real_run_chunk = ex.run_chunk
    monkeypatch.setattr(
        ex, "run_chunk", lambda *a, **kw: chunks.append(a) or real_run_chunk(*a, **kw)
    )

    report = engine.warmup(None)

    # no sampling happened: no chunk ran, no batch was counted
    assert chunks == []
    assert ex._m_batches.value() == 0
    # the full (batch x seq) grid at the config nfe, all fresh compiles
    assert report["programs"] == len(BATCHES) * len(SEQS)
    assert report["fresh"] == report["programs"]
    assert report["disk"] == 0 and report["memory"] == 0
    assert len(engine.compile_cache()) == report["programs"]
    assert {g["nfe"] for g in report["grid"]} == {ex.solver_config.nfe}
    # instruments agree
    assert ex._m_warmup_total.value() == report["programs"]
    assert ex._m_warmup_done.value() == report["programs"]
    assert ex._m_warmup_inflight.value() == 0
    assert ex._m_warmup_wall.value() > 0
    assert engine.warmup_status()["state"] == "done"


def test_warmed_engine_serves_grid_with_zero_fresh_compiles(engine, analytic):
    engine.warmup(None)
    fresh_after_warmup = engine.compile_stats()["fresh"]

    cold = BatchedSampler(
        OracleDenoiser(analytic),
        analytic.schedule,
        batch_buckets=BATCHES,
        seq_buckets=SEQS,
    )
    for r in grid_requests(nfe=engine.executor.solver_config.nfe):
        _, warm_fut = engine.submit_with_future(r)
        engine.drain(None)
        _, cold_fut = cold.submit_with_future(r)
        cold.drain(None)
        # warmed programs == cold-compiled programs, bit for bit
        np.testing.assert_array_equal(
            np.asarray(warm_fut.result().x0), np.asarray(cold_fut.result().x0)
        )
    # every serving-path acquisition was a memory hit
    assert engine.compile_stats()["fresh"] == fresh_after_warmup


def test_warmup_progress_callback_counts_grid(engine):
    calls = []
    engine.warmup(None, progress=lambda done, total: calls.append((done, total)))
    n = len(BATCHES) * len(SEQS)
    assert calls == [(i, n) for i in range(1, n + 1)]


def test_second_warmup_is_memory_hits(engine):
    first = engine.warmup(None)
    again = engine.warmup(None)
    assert again["memory"] == first["programs"]
    assert again["fresh"] == 0


def test_warmup_extra_nfes_extend_grid(engine):
    report = engine.warmup(None, nfes=(6, 10))
    assert report["programs"] == 2 * len(BATCHES) * len(SEQS)
    assert {g["nfe"] for g in report["grid"]} == {6, 10}


def test_warmup_rejects_unserveable_grid(engine):
    # ERA needs nfe >= k; a grid no request could use must fail the boot
    with pytest.raises(ValueError):
        engine.warmup(None, nfes=(2,))
    assert len(engine.compile_cache()) == 0


def test_warmup_without_ladder_needs_seq_lens(analytic):
    eng = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=BATCHES
    )
    with pytest.raises(ValueError, match="seq_lens"):
        eng.warmup(None)
    report = eng.warmup(None, seq_lens=(6,))
    assert report["programs"] == len(BATCHES)
    _, fut = eng.submit_with_future(SampleRequest(batch=1, seq_len=6, nfe=10, seed=0))
    eng.drain(None)
    fut.result()
    assert eng.compile_stats()["memory"] == 1


def test_warmup_kwargs_follow_engine_config():
    assert warmup_kwargs(EngineConfig(warmup="none")) is None
    kw = warmup_kwargs(
        EngineConfig(warmup="grid", nfe=8, warmup_seq_lens=(16,))
    )
    assert kw == {"nfes": (8,), "seq_lens": (16,)}
    # with an NFE ladder the ladder drives the warmup grid, not the
    # config's single nfe — warmup defaults to |nfe_buckets| step counts
    kw = warmup_kwargs(EngineConfig(warmup="grid", nfe=8, nfe_buckets=(8, 16)))
    assert kw == {"nfes": None, "seq_lens": None}
    with pytest.raises(ValueError, match="warmup"):
        build_engine(None, None, EngineConfig(warmup="bogus"))


# ---------------------------------------------------------------------------
# NFE-bucketed warmup: the grid is |nfe_buckets| wide, not |nfes|
# ---------------------------------------------------------------------------

NFE_BUCKETS = (8, 16)


def _nfe_bucketed_engine(analytic):
    return BatchedSampler(
        OracleDenoiser(analytic),
        analytic.schedule,
        batch_buckets=BATCHES,
        seq_buckets=SEQS,
        nfe_buckets=NFE_BUCKETS,
    )


def test_warmup_grid_bounded_by_nfe_buckets(analytic):
    eng = _nfe_bucketed_engine(analytic)
    report = eng.warmup(None)
    assert report["programs"] == len(BATCHES) * len(SEQS) * len(NFE_BUCKETS)
    assert {g["nfe"] for g in report["grid"]} == set(NFE_BUCKETS)

    # explicit nfes fold onto their buckets: eight distinct traffic NFEs
    # warm |nfe_buckets| step counts, not eight
    eng2 = _nfe_bucketed_engine(analytic)
    report2 = eng2.warmup(None, nfes=(5, 6, 7, 8, 9, 12, 14, 16))
    assert report2["programs"] == (
        len(BATCHES) * len(SEQS) * len(NFE_BUCKETS)
    )
    assert {g["nfe"] for g in report2["grid"]} == set(NFE_BUCKETS)


def test_warmed_engine_serves_mixed_nfes_memory_hit_only(analytic):
    eng = _nfe_bucketed_engine(analytic)
    eng.warmup(None)
    fresh_after_warmup = eng.compile_stats()["fresh"]
    futures = []
    for i, (nfe, seq) in enumerate(
        [(5, 3), (8, 4), (10, 7), (16, 8), (6, 5), (13, 2)]
    ):
        _, fut = eng.submit_with_future(
            SampleRequest(batch=1, seq_len=seq, nfe=nfe, seed=i)
        )
        futures.append((fut, nfe))
        eng.drain(None)
    for fut, nfe in futures:
        res = fut.result()
        assert res.padded_nfe in NFE_BUCKETS and res.padded_nfe >= nfe
    # post-warmup mixed-NFE serving is pure memory hits
    assert eng.compile_stats()["fresh"] == fresh_after_warmup


# ---------------------------------------------------------------------------
# persistent compilation cache across process boots
# ---------------------------------------------------------------------------


def _boot_subprocess(cache_dir, timeout=600):
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(tests_dir)
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, os.path.join(tests_dir, "_coldstart_boot_main.py"),
         str(cache_dir)],
        capture_output=True, text=True, timeout=timeout, cwd=root, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_persistent_cache_round_trip_across_boots(tmp_path):
    cache_dir = tmp_path / "compile-cache"
    first = _boot_subprocess(cache_dir)
    assert first["warmup"]["fresh"] == first["warmup"]["programs"] > 0
    assert first["warmup"]["disk"] == 0
    assert len(os.listdir(cache_dir)) > 0  # programs hit the disk

    second = _boot_subprocess(cache_dir)
    # the redeploy boot loads instead of compiling ...
    assert second["warmup"]["fresh"] < first["warmup"]["fresh"]
    assert second["warmup"]["disk"] > 0
    assert second["warmup"]["disk"] + second["warmup"]["fresh"] == (
        second["warmup"]["programs"]
    )
    # ... and serves the same numbers
    assert second["x0_sum"] == first["x0_sum"]


def test_cache_configured_after_first_compile_still_takes_effect(
    analytic, tmp_path
):
    """Regression: jax latches its cache handle at the first compile of
    the process; configure_persistent_cache must un-latch it or a cache
    dir configured after any compile is silently ignored."""
    from repro.serving import configure_persistent_cache

    def boot():
        eng = BatchedSampler(
            OracleDenoiser(analytic), analytic.schedule,
            batch_buckets=(1,), seq_buckets=(4,),
        )
        return eng.warmup(None)

    boot()  # a compile before any cache dir exists (latches jax's handle)
    configure_persistent_cache(str(tmp_path / "cache"))
    try:
        assert boot()["fresh"] == 1  # writes
        assert boot()["disk"] == 1  # reads
    finally:
        # drop the dir AND re-latch, or every later compile in this pytest
        # process would keep reading/writing the tmp cache
        from jax._src import compilation_cache as _cc

        jax.config.update("jax_compilation_cache_dir", None)
        _cc.reset_cache()


# ---------------------------------------------------------------------------
# /readyz gates on warmup; /healthz stays liveness
# ---------------------------------------------------------------------------


def _ready_door(analytic, warmup):
    eng = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule,
        batch_buckets=BATCHES, seq_buckets=SEQS,
    )
    return serve_frontdoor(
        eng, None, SchedulerPolicy(max_wait_ms=5.0), warmup=warmup
    )


def test_readyz_gates_on_warmup(analytic):
    release = threading.Event()
    started = threading.Event()

    def slow_warmup():
        started.set()
        assert release.wait(timeout=60)
        return {"programs": 0}

    door = _ready_door(analytic, slow_warmup)
    try:
        client = FrontDoorClient(door.url, timeout=60)
        assert started.wait(timeout=60)
        # warmup held open: not ready, but alive
        not_ready = client.readyz()
        assert not_ready["ready"] is False
        assert "warmup" in not_ready
        assert client.healthz()["ok"] is True
        assert door.ready is False

        release.set()
        deadline = threading.Event()
        for _ in range(600):
            if client.readyz()["ready"]:
                break
            deadline.wait(0.05)
        ready = client.readyz()
        assert ready["ready"] is True
        assert door.ready is True
    finally:
        release.set()
        door.stop()


def test_readyz_stays_503_when_warmup_fails(analytic):
    def broken_warmup():
        raise RuntimeError("no such solver")

    door = _ready_door(analytic, broken_warmup)
    try:
        client = FrontDoorClient(door.url, timeout=60)
        door._warmup_thread.join(timeout=60)
        payload = client.readyz()
        assert payload["ready"] is False
        assert "no such solver" in payload["error"]
        assert client.healthz()["ok"] is True  # liveness unaffected
    finally:
        door.stop()


def test_readyz_immediate_without_warmup(analytic):
    door = _ready_door(analytic, None)
    try:
        assert FrontDoorClient(door.url, timeout=60).readyz()["ready"] is True
    finally:
        door.stop()


def test_readyz_with_real_grid_warmup(analytic):
    cfg = EngineConfig(nfe=6, k=3, batch_buckets=BATCHES, seq_buckets=SEQS,
                       warmup="grid")
    eng = build_engine(OracleDenoiser(analytic), analytic.schedule, cfg)
    door = serve_frontdoor(
        eng, None, SchedulerPolicy(max_wait_ms=5.0),
        warmup=warmup_kwargs(cfg),
    )
    try:
        client = FrontDoorClient(door.url, timeout=600)
        waiter = threading.Event()
        for _ in range(1200):
            if client.readyz()["ready"]:
                break
            waiter.wait(0.1)
        payload = client.readyz()
        assert payload["ready"] is True
        assert payload["warmup"]["state"] == "done"
        assert payload["warmup"]["total"] == len(BATCHES) * len(SEQS)
        # first request of a warmed shape is a memory hit, not a compile
        fresh_before = eng.compile_stats()["fresh"]
        res = client.sample(SampleRequest(batch=2, seq_len=8, nfe=6, seed=3))
        assert res.x0.shape == (2, 8, D_MODEL)
        assert eng.compile_stats()["fresh"] == fresh_before
    finally:
        door.stop()
