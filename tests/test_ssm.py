import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.ssm import (
    chunked_linear_scan,
    mamba,
    mamba_init_state,
    mamba_specs,
    mlstm_chunkwise,
    mlstm_step,
    mlstm_zero_state,
)
from repro.models import layers as L


def _mlstm_inputs(seed=0, B=2, S=33, nh=3, hd=8):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S, nh, hd))
    k = jax.random.normal(ks[1], (B, S, nh, hd))
    v = jax.random.normal(ks[2], (B, S, nh, hd))
    ip = jax.random.normal(ks[3], (B, S, nh)) * 2
    lf = -jax.nn.softplus(-jax.random.normal(ks[4], (B, S, nh)) * 2)
    return q, k, v, ip, lf


def _mlstm_sequential_ref(q, k, v, ip, lf):
    B, S, nh, hd = q.shape
    C = np.zeros((B, nh, hd, hd))
    n = np.zeros((B, nh, hd))
    hs = []
    qf, kf, vf = (np.asarray(x, np.float64) for x in (q, k, v))
    ipn, lfn = np.asarray(ip, np.float64), np.asarray(lf, np.float64)
    for t in range(S):
        f, i = np.exp(lfn[:, t]), np.exp(ipn[:, t])
        C = C * f[..., None, None] + (i[..., None] * kf[:, t])[..., :, None] * vf[:, t][..., None, :]
        n = n * f[..., None] + i[..., None] * kf[:, t]
        den = np.maximum(np.abs(np.sum(n * qf[:, t], -1)), 1.0)
        hs.append(np.einsum("bnde,bnd->bne", C, qf[:, t]) / den[..., None])
    return np.stack(hs, 1)


@pytest.mark.parametrize("chunk", [1, 8, 33, 64])
def test_mlstm_chunkwise_matches_sequential(chunk):
    q, k, v, ip, lf = _mlstm_inputs()
    ref = _mlstm_sequential_ref(q, k, v, ip, lf)
    h, _ = mlstm_chunkwise(q, k, v, ip, lf, mlstm_zero_state(2, 3, 8), chunk)
    np.testing.assert_allclose(np.asarray(h), ref, atol=1e-4)


def test_mlstm_step_matches_chunkwise():
    q, k, v, ip, lf = _mlstm_inputs(S=17)
    h_all, _ = mlstm_chunkwise(q, k, v, ip, lf, mlstm_zero_state(2, 3, 8), 8)
    st = mlstm_zero_state(2, 3, 8)
    for t in range(17):
        h1, st = mlstm_step(
            q[:, t : t + 1], k[:, t : t + 1], v[:, t : t + 1],
            ip[:, t : t + 1], lf[:, t : t + 1], st,
        )
        np.testing.assert_allclose(
            np.asarray(h1[:, 0]), np.asarray(h_all[:, t]), atol=1e-4
        )


def test_mlstm_state_carry_across_chunks():
    """Processing [0:S] at once == processing [0:m] then [m:S]."""
    q, k, v, ip, lf = _mlstm_inputs(S=24)
    full, _ = mlstm_chunkwise(q, k, v, ip, lf, mlstm_zero_state(2, 3, 8), 8)
    h1, st = mlstm_chunkwise(
        q[:, :10], k[:, :10], v[:, :10], ip[:, :10], lf[:, :10],
        mlstm_zero_state(2, 3, 8), 8,
    )
    h2, _ = mlstm_chunkwise(
        q[:, 10:], k[:, 10:], v[:, 10:], ip[:, 10:], lf[:, 10:], st, 8
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([h1, h2], 1)), np.asarray(full), atol=1e-4
    )


def test_linear_scan_vs_numpy():
    key = jax.random.PRNGKey(3)
    a = jax.random.uniform(key, (2, 19, 5))
    b = jax.random.normal(key, (2, 19, 5))
    hs, hl = chunked_linear_scan(a, b, jnp.zeros((2, 5)), 4)
    h = np.zeros((2, 5))
    for t in range(19):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        np.testing.assert_allclose(np.asarray(hs[:, t]), h, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), h, atol=1e-5)


def test_mamba_seq_vs_step_decode():
    """Full-sequence mamba == token-by-token recurrent decode."""
    cfg = get_config("hymba-1.5b", smoke=True)
    p = L.init_params(mamba_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model))
    full, _ = mamba(p, x, cfg)
    st = mamba_init_state(cfg, 2, jnp.float32)
    outs = []
    for t in range(12):
        o, st = mamba(p, x[:, t : t + 1], cfg, state=st, mode="decode")
        outs.append(o[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(full), atol=2e-4
    )
