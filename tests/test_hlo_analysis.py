import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H


SYNTHETIC = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%z, %x)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_loop_accounting():
    res = H.analyze(SYNTHETIC)
    # dot: 2*8*8*8 = 1024 flops, x5 loop trips
    assert res["flops"] == 5 * 1024
    # all-reduce result: 8*8*4 = 256 B, x5
    assert res["collectives"]["all-reduce"] == 5 * 256


def test_real_module_flops_exact():
    """Known matmul inside a fori_loop: analyzer must count trips."""

    def f(x, w):
        def body(_, x):
            return jnp.tanh(x @ w)

        return jax.lax.fori_loop(0, 7, body, x)

    comp = (
        jax.jit(f)
        .lower(
            jax.ShapeDtypeStruct((32, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
        )
        .compile()
    )
    res = H.analyze(comp.as_text())
    expect = 7 * 2 * 32 * 64 * 64
    assert abs(res["flops"] - expect) / expect < 0.01, res["flops"]


def test_nested_loops_multiply():
    def f(x, w):
        def outer(_, x):
            def inner(_, y):
                return y @ w

            return jax.lax.fori_loop(0, 3, inner, x)

        return jax.lax.fori_loop(0, 4, outer, x)

    comp = (
        jax.jit(f)
        .lower(
            jax.ShapeDtypeStruct((16, 16), jnp.float32),
            jax.ShapeDtypeStruct((16, 16), jnp.float32),
        )
        .compile()
    )
    res = H.analyze(comp.as_text())
    expect = 12 * 2 * 16 * 16 * 16
    assert abs(res["flops"] - expect) / expect < 0.01, res["flops"]


def test_shape_bytes():
    assert H._shape_bytes_of_type("f32[2,3]") == 24
    assert H._shape_bytes_of_type("bf16[10]") == 20
    assert H._shape_bytes_of_type("(s32[], f32[4,4])") == 4 + 64
