import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ERAConfig, get_solver, linear_schedule
from repro.data import DataConfig, GaussianMixtureLatents
from repro.models import build_model
from repro.models.diffusion import DiffusionLM
from repro.training import (
    OptimizerConfig,
    make_diffusion_train_step,
    train,
)

KEY = jax.random.PRNGKey(0)


def test_eps_shapes_and_dtype():
    cfg = get_config("llama3.2-1b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(KEY)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model))
    eps = dlm.eps(params, x, jnp.float32(0.5))
    assert eps.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(eps)))


def test_loss_finite_and_decreases():
    cfg = get_config("qwen2-1.5b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(KEY)
    sched = linear_schedule()
    dc = DataConfig(vocab_size=1, seq_len=8, batch_size=8, kind="diffusion",
                    d_model=cfg.d_model)
    loader = GaussianMixtureLatents(dc).batches()
    step = make_diffusion_train_step(
        dlm, OptimizerConfig(lr=2e-3, warmup_steps=3, total_steps=40), sched
    )
    res = train(step, params, loader, 40, log_every=39, print_fn=lambda s: None)
    assert res.history[-1]["loss"] < res.history[0]["loss"]


def test_trained_model_samples_with_era():
    """End-to-end: train briefly, then ERA-sample; samples should move
    toward the data distribution (mean closer than pure noise)."""
    cfg = get_config("llama3.2-1b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(KEY)
    sched = linear_schedule()
    dc = DataConfig(vocab_size=1, seq_len=8, batch_size=16, kind="diffusion",
                    d_model=cfg.d_model, num_modes=2, seed=3)
    data = GaussianMixtureLatents(dc)
    step = make_diffusion_train_step(
        dlm, OptimizerConfig(lr=2e-3, warmup_steps=5, total_steps=60), sched
    )
    res = train(step, params, data.batches(), 60, log_every=100,
                print_fn=lambda s: None)
    mu, var = data.moments()

    xT = jax.random.normal(KEY, (64, 8, cfg.d_model))
    out = get_solver("era")(
        dlm.eps_fn(res.params), xT, sched, ERAConfig(nfe=10, k=3)
    )
    got_mu = np.asarray(jnp.mean(out.x0, axis=(0, 1)))
    err_model = float(np.linalg.norm(got_mu - mu))
    err_noise = float(np.linalg.norm(np.zeros_like(mu) - mu))
    assert err_model < err_noise, (err_model, err_noise)


@pytest.mark.parametrize(
    "name",
    [
        "llama3.2-1b", "qwen2-1.5b", "whisper-base", "deepseek-v2-lite-16b",
        "xlstm-350m", "mixtral-8x7b", "deepseek-67b", "hymba-1.5b",
        "paligemma-3b", "minitron-4b",
    ],
)
def test_era_samples_every_architecture(name):
    """DESIGN.md §Arch-applicability: the paper's solver wraps every
    assigned backbone family as a diffusion-LM denoiser (enc-dec runs
    decoder-only, hybrids run their SSM branches per NFE)."""
    from repro.core import ERAConfig, get_solver, linear_schedule

    cfg = get_config(name, smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(KEY)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model))
    out = get_solver("era")(
        dlm.eps_fn(params), x, linear_schedule(), ERAConfig(nfe=6, k=3)
    )
    assert out.x0.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out.x0)))
