"""`hypothesis` facade with a deterministic fallback.

CI installs the real hypothesis (the `test` extra in pyproject.toml); bare
environments without it still collect and run the property tests through
this shim, which replays a fixed-seed random sample of each strategy.  Only
the strategy surface this test suite uses is implemented.
"""

from __future__ import annotations

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # fallback: seeded mini property-test driver
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw_fn(rng)))

    class st:  # noqa: N801 - mimics hypothesis.strategies
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique=False):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                if not unique:
                    return [elements.draw(rng) for _ in range(n)]
                seen = []
                while len(seen) < n:
                    v = elements.draw(rng)
                    if v not in seen:
                        seen.append(v)
                return seen

            return _Strategy(draw)

    def given(*strategies):
        def decorate(fn):
            # NB: no functools.wraps — pytest must see the zero-arg
            # signature, not the wrapped one (it would look for fixtures)
            def wrapper():
                rng = random.Random(1234)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper

        return decorate

    def settings(max_examples=10, deadline=None, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn

        return decorate
