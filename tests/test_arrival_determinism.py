"""Arrival-order determinism wall for the continuous-batching scheduler.

The serving contract: a seeded request's ``x0`` is **bit-identical**
whether it runs

* via the sync engine's ``drain()`` (fused with whoever was pending),
* via the async scheduler under an arbitrary arrival interleaving — client
  threads racing, random delays, whatever batch compositions the policy
  happens to form — or
* solo through :class:`SamplerService` (exact-size batch, no padding).

Per-sample ERS is what makes this hold (each row's delta_eps measurement
and Lagrange base selection read only its own row), and this property is
what makes continuous batching correctness-preserving at all: scheduler
timing must never leak into results.  Randomized over seq_len / nfe / seeds
/ arrival delays via `tests/_hypothesis_compat.py` (real hypothesis in CI,
the deterministic shim in bare environments), and re-checked on the
8-virtual-device mesh fixture.

PR-4 extends the wall to **mixed-solver streams**: requests routed to
different registry solvers (`era` / `ddim` / `dpm_solver_pp2m`) interleave
in one scheduler, batch per (solver, seq_len, nfe) queue, and every
request's x0 still matches its sync-drain and solo runs bit-for-bit.

PR-5 extends it to **mixed-seq-len streams**: with `seq_buckets` the
scheduler queues key on the seq *bucket*, so requests of different lengths
share fused batches (right-padded + length-masked), and every request's x0
still matches its exact-shape solo run bit-for-bit under any arrival
interleaving (see also `tests/test_seq_bucketing.py`).

PR-10 extends it to **mixed-NFE streams**: with `nfe_buckets` the queues
key on the NFE *bucket*, so 10/18/25-NFE requests share step-masked fused
batches.  The step-masked contract is composition-shaped: a request's x0
depends only on the compiled batch shape it ran at — never on its
batch-mates' values, NFEs, or row order — so async results are bitwise
equal to the sync drain whenever the scheduler formed the same batch
bucket, and within float tolerance (last-ulp transcendental rounding on
batch-shaped time columns) when it formed a different one (see also
`tests/test_nfe_bucketing.py`).
"""

import random
import threading
import time

import numpy as np

from _hypothesis_compat import given, settings, st
from conftest import AnalyticGaussian, OracleDenoiser
from repro.core import ERAConfig
from repro.serving import (
    AsyncBatchedSampler,
    BatchedSampler,
    SampleRequest,
    SamplerService,
    SchedulerPolicy,
)

# module-level: the shim's `given` produces zero-arg tests, so no fixtures
ANALYTIC = AnalyticGaussian()

# solvers a mixed stream cycles through (None = the engine default, era)
MIXED_SOLVERS = (None, "ddim", "dpm_solver_pp2m", "era")


def _requests(n, seq_len, nfe, seed0, mixed=False):
    return [
        SampleRequest(
            batch=1,
            seq_len=seq_len,
            nfe=nfe,
            solver=MIXED_SOLVERS[i % len(MIXED_SOLVERS)] if mixed else None,
            seed=seed0 + i,
        )
        for i in range(n)
    ]


def _engine(mesh=None, seq_buckets=None, nfe_buckets=None):
    return BatchedSampler(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        batch_buckets=(2, 4, 8),
        mesh=mesh,
        seq_buckets=seq_buckets,
        nfe_buckets=nfe_buckets,
    )


def _sync_results(reqs, mesh=None, seq_buckets=None, nfe_buckets=None):
    engine = _engine(mesh, seq_buckets, nfe_buckets)
    tickets = [engine.submit(r) for r in reqs]
    results = engine.drain(params=None)
    return [results[t] for t in tickets]


def _sync_x0(reqs, mesh=None, seq_buckets=None):
    return [
        np.asarray(r.x0)
        for r in _sync_results(reqs, mesh=mesh, seq_buckets=seq_buckets)
    ]


def _async_results(
    reqs, delay_seed, mesh=None, seq_buckets=None, nfe_buckets=None
):
    """Run through the scheduler with racing client threads and randomized
    submission delays — arbitrary arrival interleavings and batch
    compositions."""
    engine = _engine(mesh, seq_buckets, nfe_buckets)
    rng = random.Random(delay_seed)
    futures: dict[int, object] = {}
    lock = threading.Lock()
    with AsyncBatchedSampler(
        engine,
        params=None,
        policy=SchedulerPolicy(max_wait_ms=2.0, target_occupancy=0.5),
    ) as sched:

        def client(my_reqs):
            for i, r in my_reqs:
                time.sleep(rng.uniform(0.0, 0.004))
                fut = sched.submit(r)
                with lock:
                    futures[i] = fut

        indexed = list(enumerate(reqs))
        threads = [
            threading.Thread(target=client, args=(indexed[0::2],)),
            threading.Thread(target=client, args=(indexed[1::2],)),
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        out = {i: f.result(timeout=120) for i, f in futures.items()}
    return [out[i] for i in range(len(reqs))]


def _async_x0(reqs, delay_seed, mesh=None, seq_buckets=None):
    return [
        np.asarray(r.x0)
        for r in _async_results(
            reqs, delay_seed, mesh=mesh, seq_buckets=seq_buckets
        )
    ]


def _solo_x0(reqs, mesh=None):
    svc = SamplerService(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        solver_config=ERAConfig(per_sample=True),
        mesh=mesh,
    )
    return [np.asarray(svc.sample(None, r).x0) for r in reqs]


@settings(max_examples=4, deadline=None)
@given(
    st.integers(min_value=2, max_value=5),       # co-arriving requests
    st.integers(min_value=2, max_value=8),       # seq_len
    st.integers(min_value=0, max_value=4),       # nfe headroom above k=4
    st.integers(min_value=0, max_value=10_000),  # request seed base
    st.integers(min_value=0, max_value=10_000),  # arrival-delay seed
)
def test_x0_bit_identical_across_sync_async_and_solo(
    n, seq_len, extra, seed0, delay_seed
):
    reqs = _requests(n, seq_len, nfe=5 + extra, seed0=seed0)
    sync = _sync_x0(reqs)
    asyn = _async_x0(reqs, delay_seed)
    solo = _solo_x0(reqs)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            asyn[i],
            sync[i],
            err_msg=f"async vs sync diverged for seed {r.seed} "
            f"(n={n}, seq_len={seq_len}, nfe={r.nfe})",
        )
        np.testing.assert_array_equal(
            asyn[i],
            solo[i],
            err_msg=f"async vs solo diverged for seed {r.seed} "
            f"(n={n}, seq_len={seq_len}, nfe={r.nfe})",
        )


@settings(max_examples=3, deadline=None)
@given(
    st.integers(min_value=3, max_value=6),       # co-arriving requests
    st.integers(min_value=2, max_value=8),       # seq_len
    st.integers(min_value=0, max_value=4),       # nfe headroom above k=4
    st.integers(min_value=0, max_value=10_000),  # request seed base
    st.integers(min_value=0, max_value=10_000),  # arrival-delay seed
)
def test_x0_bit_identical_for_mixed_solver_streams(
    n, seq_len, extra, seed0, delay_seed
):
    """The same wall with requests routed to different solvers: the
    scheduler batches per (solver, seq_len, nfe) queue, and no request's
    result depends on which solvers its neighbours asked for."""
    reqs = _requests(n, seq_len, nfe=5 + extra, seed0=seed0, mixed=True)
    sync = _sync_x0(reqs)
    asyn = _async_x0(reqs, delay_seed)
    solo = _solo_x0(reqs)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            asyn[i],
            sync[i],
            err_msg=f"async vs sync diverged for solver {r.solver} "
            f"seed {r.seed} (n={n}, seq_len={seq_len}, nfe={r.nfe})",
        )
        np.testing.assert_array_equal(
            asyn[i],
            solo[i],
            err_msg=f"async vs solo diverged for solver {r.solver} "
            f"seed {r.seed} (n={n}, seq_len={seq_len}, nfe={r.nfe})",
        )


@settings(max_examples=3, deadline=None)
@given(
    st.integers(min_value=3, max_value=6),       # co-arriving requests
    st.integers(min_value=1, max_value=8),       # first request's seq_len
    st.integers(min_value=0, max_value=4),       # nfe headroom above k=4
    st.integers(min_value=0, max_value=10_000),  # request seed base
    st.integers(min_value=0, max_value=10_000),  # arrival-delay seed
)
def test_x0_bit_identical_for_mixed_seq_len_streams(
    n, seq0, extra, seed0, delay_seed
):
    """The same wall with requests of *different* seq_lens fusing into one
    seq-bucketed batch: the scheduler queues key on the bucket, so any
    arrival interleaving can mix lengths in a chunk — and no request's x0
    may depend on which lengths its batch-mates brought, nor on how far it
    was padded."""
    nfe = 5 + extra
    buckets = (4, 8)
    reqs = [
        SampleRequest(
            batch=1,
            seq_len=(seq0 + 3 * i) % 8 + 1,
            nfe=nfe,
            seed=seed0 + i,
        )
        for i in range(n)
    ]
    sync = _sync_x0(reqs, seq_buckets=buckets)
    asyn = _async_x0(reqs, delay_seed, seq_buckets=buckets)
    solo = _solo_x0(reqs)  # exact-shape, no bucketing anywhere
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            asyn[i],
            sync[i],
            err_msg=f"async vs sync diverged for seq_len {r.seq_len} "
            f"seed {r.seed} (n={n}, nfe={r.nfe})",
        )
        np.testing.assert_array_equal(
            asyn[i],
            solo[i],
            err_msg=f"bucketed async vs exact-shape solo diverged for "
            f"seq_len {r.seq_len} seed {r.seed} (n={n}, nfe={r.nfe})",
        )


NFE_BUCKETS = (18, 32)
NFE_STREAM = (10, 18, 25)  # 10/18 share the 18-bucket; 25 rides the 32


def _assert_composition_shaped(asyn, sync, label):
    """The step-masked determinism contract: bitwise whenever the
    scheduler formed the same batch bucket as the sync drain, float-
    tolerance (last-ulp transcendental rounding) when it formed a
    different one."""
    for i, (a, s) in enumerate(zip(asyn, sync)):
        if a.padded_batch == s.padded_batch:
            np.testing.assert_array_equal(
                np.asarray(a.x0), np.asarray(s.x0),
                err_msg=f"{label}: async vs sync diverged at identical "
                f"batch bucket {a.padded_batch} (request {i})",
            )
        else:
            np.testing.assert_allclose(
                np.asarray(a.x0), np.asarray(s.x0), atol=1e-6,
                err_msg=f"{label}: async (bucket {a.padded_batch}) vs "
                f"sync (bucket {s.padded_batch}) exceeded the cross-"
                f"composition tolerance (request {i})",
            )


@settings(max_examples=3, deadline=None)
@given(
    st.integers(min_value=3, max_value=6),       # co-arriving requests
    st.integers(min_value=2, max_value=8),       # seq_len
    st.integers(min_value=0, max_value=10_000),  # request seed base
    st.integers(min_value=0, max_value=10_000),  # arrival-delay seed
)
def test_x0_deterministic_for_mixed_nfe_streams(n, seq_len, seed0, delay_seed):
    """The wall with requests of *different* NFEs fusing into shared
    step-masked buckets: the scheduler queues key on the NFE bucket, so
    any arrival interleaving can mix 10/18/25-NFE requests in a chunk —
    and no request's x0 may depend on which NFEs its batch-mates brought,
    nor on how far its steps were padded."""
    reqs = [
        SampleRequest(
            batch=1,
            seq_len=seq_len,
            nfe=NFE_STREAM[i % len(NFE_STREAM)],
            seed=seed0 + i,
        )
        for i in range(n)
    ]
    sync = _sync_results(reqs, nfe_buckets=NFE_BUCKETS)
    asyn = _async_results(reqs, delay_seed, nfe_buckets=NFE_BUCKETS)
    for i, r in enumerate(reqs):
        # every request rode a bucketed (step-masked) program
        assert asyn[i].padded_nfe in NFE_BUCKETS, r.nfe
        assert sync[i].padded_nfe == asyn[i].padded_nfe
    _assert_composition_shaped(
        asyn, sync, f"mixed-NFE (n={n}, seq_len={seq_len}, seed0={seed0})"
    )
    # and the scalar-time solo runs anchor correctness to float tolerance
    solo = _solo_x0(reqs)
    for i, r in enumerate(reqs):
        np.testing.assert_allclose(
            np.asarray(asyn[i].x0), solo[i], atol=1e-6,
            err_msg=f"bucketed async vs exact-NFE solo diverged for "
            f"nfe {r.nfe} seed {r.seed}",
        )


def test_mixed_nfe_arrival_determinism_on_mesh(mesh8):
    """The mixed-NFE wall on the 8-virtual-device mesh: step-mask pspecs
    ride the carry, and scheduler timing must not leak into results when
    the step-masked batch is sharded across devices."""
    reqs = [
        SampleRequest(
            batch=1, seq_len=6, nfe=NFE_STREAM[i % len(NFE_STREAM)],
            seed=300 + i,
        )
        for i in range(6)
    ]
    sync_mesh = _sync_results(reqs, mesh=mesh8, nfe_buckets=NFE_BUCKETS)
    async_mesh = _async_results(
        reqs, delay_seed=5, mesh=mesh8, nfe_buckets=NFE_BUCKETS
    )
    _assert_composition_shaped(async_mesh, sync_mesh, "mesh mixed-NFE")
    single = _sync_results(reqs, nfe_buckets=NFE_BUCKETS)
    for i, r in enumerate(reqs):
        np.testing.assert_allclose(
            np.asarray(async_mesh[i].x0), np.asarray(single[i].x0),
            atol=1e-5,
            err_msg=f"mesh vs single-device mixed-NFE diverged for "
            f"nfe {r.nfe} seed {r.seed}",
        )


def test_arrival_determinism_on_mesh(mesh8):
    """The same wall on the 8-virtual-device mesh: scheduler timing must not
    leak into results when the fused batch is sharded across devices."""
    reqs = _requests(5, seq_len=6, nfe=8, seed0=77)
    sync_mesh = _sync_x0(reqs, mesh=mesh8)
    async_mesh = _async_x0(reqs, delay_seed=3, mesh=mesh8)
    single = _sync_x0(reqs)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            async_mesh[i],
            sync_mesh[i],
            err_msg=f"mesh async vs mesh sync diverged for seed {r.seed}",
        )
        np.testing.assert_allclose(
            async_mesh[i],
            single[i],
            atol=1e-5,
            err_msg=f"mesh async vs single-device diverged for seed {r.seed}",
        )
