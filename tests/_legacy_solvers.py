"""Pre-refactor (PR-3-era) solver implementations, kept verbatim as parity
references.

The PR-4 solver-program refactor rewrote ``ddim``, ``explicit_adams``,
``implicit_adams_pece``, and ``dpm_solver_pp2m`` from ``lax.fori_loop`` /
eager bodies into single ``lax.scan`` programs with explicit donatable
buffers.  These are the *original* loop bodies, copied unchanged, so
``tests/test_solvers.py`` can assert the new scan programs are
**bit-identical** to what shipped before.  ``era`` and the singlestep
DPM-Solvers were not rewritten (era was already a scan; dpm_solver_2/fast
stay unrolled), so their "legacy" entry is the registry function itself.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import get_solver
from repro.core.adams import AM4, _ab_combine
from repro.core.schedules import NoiseSchedule, timesteps
from repro.core.solver_base import (
    SolverConfig,
    SolverOutput,
    buffer_append,
    buffer_init,
    ddim_step,
    trajectory_append,
    trajectory_init,
)

Array = jax.Array


def ddim_sample(eps_fn, x_init, schedule: NoiseSchedule, config: SolverConfig):
    n = config.nfe
    ts = timesteps(schedule, n, config.scheme, t_end=config.t_end)
    traj = trajectory_init(x_init, n, config.return_trajectory)

    def body(i, carry):
        x, traj = carry
        t_cur, t_next = ts[i], ts[i + 1]
        eps = eps_fn(x, t_cur)
        x = ddim_step(schedule, x, eps, t_cur, t_next)
        traj = trajectory_append(traj, i + 1, x)
        return (x, traj)

    x, traj = jax.lax.fori_loop(0, n, body, (x_init, traj))
    aux = {"trajectory": traj} if traj is not None else {}
    return SolverOutput(x0=x, nfe=jnp.int32(n), aux=aux)


def explicit_adams_sample(
    eps_fn, x_init, schedule: NoiseSchedule, config: SolverConfig, order: int = 4
):
    n = config.nfe
    ts = timesteps(schedule, n, config.scheme, t_end=config.t_end)
    dt = config.solver_dtype

    x = x_init.astype(dt)
    eps_buf, t_buf = buffer_init(x, n + 1, dt)
    e0 = eps_fn(x, ts[0]).astype(dt)
    eps_buf, t_buf = buffer_append(eps_buf, t_buf, jnp.int32(0), e0, ts[0])
    traj = trajectory_init(x, n, config.return_trajectory)

    def body(i, carry):
        x, eps_buf, t_buf, traj = carry
        t_cur, t_next = ts[i], ts[i + 1]

        branches = []
        for o in range(1, order + 1):
            branches.append(lambda _, o=o: _ab_combine(eps_buf, i, o))
        eff = jnp.minimum(i + 1, order)
        eps_c = jax.lax.switch(eff - 1, branches, None)

        x_next = ddim_step(schedule, x, eps_c, t_cur, t_next)

        def observe(_):
            return eps_fn(x_next, t_next).astype(dt)

        e_new = jax.lax.cond(
            i + 1 < n, observe, lambda _: jnp.zeros_like(x_next), None
        )
        eps_buf2, t_buf2 = buffer_append(eps_buf, t_buf, i + 1, e_new, t_next)
        traj = trajectory_append(traj, i + 1, x_next)
        return (x_next, eps_buf2, t_buf2, traj)

    x, eps_buf, t_buf, traj = jax.lax.fori_loop(
        0, n, body, (x, eps_buf, t_buf, traj)
    )
    aux = {"trajectory": traj} if traj is not None else {}
    return SolverOutput(x0=x.astype(x_init.dtype), nfe=jnp.int32(n), aux=aux)


def implicit_adams_pece_sample(
    eps_fn, x_init, schedule: NoiseSchedule, config: SolverConfig
):
    n_steps = max(config.nfe // 2, 1)
    ts = timesteps(schedule, n_steps, config.scheme, t_end=config.t_end)
    dt = config.solver_dtype

    x = x_init.astype(dt)
    eps_buf, t_buf = buffer_init(x, n_steps + 1, dt)
    e0 = eps_fn(x, ts[0]).astype(dt)
    eps_buf, t_buf = buffer_append(eps_buf, t_buf, jnp.int32(0), e0, ts[0])
    traj = trajectory_init(x, n_steps, config.return_trajectory)

    def body(i, carry):
        x, eps_buf, t_buf, traj = carry
        t_cur, t_next = ts[i], ts[i + 1]

        branches = [
            lambda _, o=o: _ab_combine(eps_buf, i, o) for o in (1, 2, 3, 4)
        ]
        eff = jnp.minimum(i + 1, 4)
        eps_p = jax.lax.switch(eff - 1, branches, None)
        x_pred = ddim_step(schedule, x, eps_p, t_cur, t_next)
        e_bar = eps_fn(x_pred, t_next).astype(dt)
        e_i = jax.lax.dynamic_index_in_dim(eps_buf, i, 0, keepdims=False)
        e_im1 = jax.lax.dynamic_index_in_dim(
            eps_buf, jnp.maximum(i - 1, 0), 0, keepdims=False
        )
        e_im2 = jax.lax.dynamic_index_in_dim(
            eps_buf, jnp.maximum(i - 2, 0), 0, keepdims=False
        )
        c0, c1, c2, c3 = AM4
        eps_c = c0 * e_bar + c1 * e_i + c2 * e_im1 + c3 * e_im2
        eps_c = jnp.where(i >= 2, eps_c, 0.5 * (e_bar + e_i))
        x_next = ddim_step(schedule, x, eps_c, t_cur, t_next)

        def observe(_):
            return eps_fn(x_next, t_next).astype(dt)

        e_new = jax.lax.cond(
            i + 1 < n_steps, observe, lambda _: jnp.zeros_like(x_next), None
        )
        eps_buf2, t_buf2 = buffer_append(eps_buf, t_buf, i + 1, e_new, t_next)
        traj = trajectory_append(traj, i + 1, x_next)
        return (x_next, eps_buf2, t_buf2, traj)

    x, eps_buf, t_buf, traj = jax.lax.fori_loop(
        0, n_steps, body, (x, eps_buf, t_buf, traj)
    )
    aux = {"trajectory": traj} if traj is not None else {}
    return SolverOutput(
        x0=x.astype(x_init.dtype), nfe=jnp.int32(2 * n_steps - 1), aux=aux
    )


def dpm_solver_pp2m_sample(
    eps_fn, x_init, schedule: NoiseSchedule, config: SolverConfig
):
    n = config.nfe
    ts = timesteps(schedule, n, "logsnr", t_end=config.t_end)
    lam = schedule.lam(ts)
    alpha, sigma = schedule.alpha(ts), schedule.sigma(ts)
    dt = config.solver_dtype

    x = x_init.astype(dt)

    def x0_of(x, i):
        e = eps_fn(x, ts[i]).astype(dt)
        return (x - sigma[i].astype(dt) * e) / alpha[i].astype(dt)

    def body(i, carry):
        x, x0_prev = carry
        x0 = x0_of(x, i)
        h = lam[i + 1] - lam[i]
        h_prev = lam[i] - lam[jnp.maximum(i - 1, 0)]
        r = h_prev / h
        use_ms = i > 0
        coef = jnp.where(use_ms, 1.0 / (2.0 * jnp.where(use_ms, r, 1.0)), 0.0)
        d = (1.0 + coef).astype(dt) * x0 - coef.astype(dt) * x0_prev
        x_next = (sigma[i + 1] / sigma[i]).astype(dt) * x - (
            alpha[i + 1] * jnp.expm1(-h)
        ).astype(dt) * d
        return (x_next, x0)

    x, _ = jax.lax.fori_loop(0, n, body, (x, jnp.zeros_like(x)))
    return SolverOutput(x0=x.astype(x_init.dtype), nfe=jnp.int32(n), aux={})


_LEGACY = {
    "ddim": ddim_sample,
    "explicit_adams": explicit_adams_sample,
    "implicit_adams_pece": implicit_adams_pece_sample,
    "dpm_solver_pp2m": dpm_solver_pp2m_sample,
}


def legacy_sample(name: str, eps_fn, x_init, schedule, config) -> SolverOutput:
    """The pre-refactor sampling entry for ``name`` (the current registry
    function for solvers the refactor did not rewrite)."""
    fn = _LEGACY.get(name)
    if fn is None:
        fn = get_solver(name)
    return fn(eps_fn, x_init, schedule, config)
