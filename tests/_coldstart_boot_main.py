"""Subprocess child for the persistent compile-cache round-trip test.

One replica boot: build the Oracle engine through ``build_engine`` with
``warmup="grid"`` and the shared ``compile_cache_dir`` from argv, run the
grid warmup, serve one request, and print a JSON record of the warmup
report / compile-source counters / an x0 checksum.  The parent runs this
twice against the same cache dir and asserts the second boot's warmup
came from disk, with bit-identical sampling output.
"""

import json
import sys

# sys.path[0] is this script's dir (tests/), so conftest resolves; the
# parent provides src/ on PYTHONPATH
from conftest import AnalyticGaussian, OracleDenoiser

from repro.serving import (
    EngineConfig,
    SampleRequest,
    build_engine,
    warmup_kwargs,
)


def main() -> None:
    cache_dir = sys.argv[1]
    analytic = AnalyticGaussian()
    cfg = EngineConfig(
        nfe=6,
        k=3,
        batch_buckets=(1, 2),
        seq_buckets=(4, 8),
        warmup="grid",
        compile_cache_dir=cache_dir,
    )
    engine = build_engine(OracleDenoiser(analytic), analytic.schedule, cfg)
    report = engine.warmup(None, **warmup_kwargs(cfg))

    _, fut = engine.submit_with_future(
        SampleRequest(batch=2, seq_len=8, nfe=6, seed=7)
    )
    engine.drain(None)
    x0 = fut.result().x0

    print(
        json.dumps(
            {
                "warmup": {
                    k: report[k]
                    for k in ("programs", "fresh", "disk", "memory")
                },
                "compile_stats": engine.compile_stats(),
                "x0_sum": float(x0.sum()),
            }
        )
    )


if __name__ == "__main__":
    main()
