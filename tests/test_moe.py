import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.moe import moe_ffn, moe_specs


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("mixtral-8x7b", smoke=True)
    p = L.init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    return cfg, p, x


def test_dropless_dropping_matches_dense_mix(setup):
    cfg, p, x = setup
    dense_cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, dispatch="dense_mix"))
    drop_cfg = cfg.with_(
        moe=dataclasses.replace(
            cfg.moe,
            dispatch="dropping",
            capacity_factor=float(cfg.moe.num_experts) / cfg.moe.top_k + 1,
        )
    )
    ref, aux_ref = moe_ffn(p, x, dense_cfg)
    got, aux_got = moe_ffn(p, x, drop_cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)
    # aux is averaged per dispatch group vs globally -> close, not identical
    assert abs(float(aux_ref["moe_aux"]) - float(aux_got["moe_aux"])) < 0.05


def test_capacity_drops_reduce_output_norm(setup):
    """Tight capacity drops tokens -> strictly less routed mass."""
    cfg, p, x = setup
    tight = cfg.with_(
        moe=dataclasses.replace(
            cfg.moe, dispatch="dropping", capacity_factor=0.25
        )
    )
    loose = cfg.with_(
        moe=dataclasses.replace(
            cfg.moe, dispatch="dropping", capacity_factor=8.0
        )
    )
    out_t, _ = moe_ffn(p, x, tight)
    out_l, _ = moe_ffn(p, x, loose)
    assert float(jnp.linalg.norm(out_t)) < float(jnp.linalg.norm(out_l))


def test_router_z_loss_scales_with_logits():
    """z-loss penalizes large router logits (keeps the router calibrated)."""
    cfg = get_config("mixtral-8x7b", smoke=True)
    p = L.init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    p_hot = dict(p, router={"w": p["router"]["w"] * 50.0})
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    _, aux = moe_ffn(p, x, cfg)
    _, aux_hot = moe_ffn(p_hot, x, cfg)
    assert float(aux_hot["moe_z"]) > float(aux["moe_z"])
    # load-balance loss is O(1) for a near-uniform random router
    assert 0.5 < float(aux["moe_aux"]) < 2.0


def test_shared_experts_always_active():
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    p = L.init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    out, _ = moe_ffn(p, x, cfg)
    # zero out routed experts: output should become exactly the shared path
    p2 = dict(p)
    p2["experts"] = jax.tree.map(jnp.zeros_like, p["experts"])
    out2, _ = moe_ffn(p2, x, cfg)
    shared_only = L.mlp(p["shared"], x, "silu")
    np.testing.assert_allclose(np.asarray(out2), np.asarray(shared_only), atol=1e-5)


def test_decode_single_token_not_dropped():
    """top-k assignments of a single token always fit capacity."""
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    p = L.init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model))
    dense_cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, dispatch="dense_mix"))
    ref, _ = moe_ffn(p, x, dense_cfg)
    got, _ = moe_ffn(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)
