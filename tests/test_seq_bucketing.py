"""Padding-invariance wall for mixed-seq-len fusion (seq bucketing).

The serving contract: with ``seq_buckets`` configured, requests whose
``seq_len`` differ fuse into one compiled batch — each request's rows are
right-padded to the smallest bucket that fits, the denoiser masks pad keys,
and the solver masks its sequence reductions — and a request's ``x0`` and
per-sample ERS basis selections are **bit-identical** to its exact-shape
solo run.  What makes the bitwise claim hold (not just "close"): the
denoiser's pad-key attention bias adds exact ``0.0`` to valid scores, and
ERA's error norms reduce features at fixed per-position shape and then
accumulate positions with a strictly sequential scan, so zero-masked pad
positions append exact ``acc + 0.0`` no-ops instead of re-associating the
reduction (see ``era._seq_sq_sums``).

Also walled here: the compile count is bounded by the bucket ladder (not by
distinct seq_lens), over-ladder requests are rejected at submit with an
actionable message, ``padded_seq_len`` is surfaced through results and the
facade info dict, unmaskable denoisers / non-fusable configs fall back to
exact-shape grouping, and the mesh8 mixed-length drain matches.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from conftest import AnalyticGaussian, OracleDenoiser
from repro.core import ERAConfig
from repro.serving import (
    AsyncBatchedSampler,
    BatchedSampler,
    SampleRequest,
    SamplerService,
    result_keys as K,
)

# module-level: the shim's `given` produces zero-arg tests, so no fixtures
ANALYTIC = AnalyticGaussian()

SEQ_BUCKETS = (4, 8)


def _bucketed_engine(mesh=None, seq_buckets=SEQ_BUCKETS, **kw):
    return BatchedSampler(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        batch_buckets=(2, 4, 8),
        seq_buckets=seq_buckets,
        mesh=mesh,
        **kw,
    )


def _exact_engine(mesh=None):
    return BatchedSampler(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        batch_buckets=None,
        mesh=mesh,
    )


def _solo(req, mesh=None):
    """Exact-shape solo run of one request (no seq bucketing anywhere)."""
    engine = _exact_engine(mesh=mesh)
    ticket = engine.submit(req)
    return engine.drain(None)[ticket]


@settings(max_examples=4, deadline=None)
@given(
    st.integers(min_value=2, max_value=4),       # co-arriving requests
    st.integers(min_value=1, max_value=8),       # first request's seq_len
    st.integers(min_value=0, max_value=3),       # nfe headroom above k=4
    st.integers(min_value=0, max_value=10_000),  # request seed base
)
def test_padding_invariance_bitwise(n, seq0, extra, seed0):
    """A request padded from L to bucket(L) inside a fused mixed-length
    batch produces bit-identical x0, delta_eps history, and ERS basis
    selections to its exact-shape solo run."""
    nfe = 5 + extra
    # a mix of lengths that spans both buckets and hits the bucket edges
    lens = [(seq0 + 3 * i) % 8 + 1 for i in range(n)]
    reqs = [
        SampleRequest(batch=1 + (i % 2), seq_len=lens[i], nfe=nfe,
                      seed=seed0 + i)
        for i in range(n)
    ]
    engine = _bucketed_engine()
    tickets = [engine.submit(r) for r in reqs]
    fused = engine.drain(None)
    for ticket, req in zip(tickets, reqs):
        got = fused[ticket]
        ref = _solo(req)
        assert got.x0.shape == (req.batch, req.seq_len,
                                OracleDenoiser.D_MODEL)
        np.testing.assert_array_equal(
            np.asarray(got.x0), np.asarray(ref.x0),
            err_msg=f"x0 diverged for seq_len={req.seq_len} "
            f"(padded to {got.padded_seq_len}, seed={req.seed})",
        )
        np.testing.assert_array_equal(
            np.asarray(got.aux["ers_selection_history"]),
            np.asarray(ref.aux["ers_selection_history"]),
            err_msg=f"ERS basis selection flipped under padding "
            f"(seq_len={req.seq_len} -> {got.padded_seq_len})",
        )
        np.testing.assert_array_equal(
            np.asarray(got.aux["delta_eps_history_per_sample"]),
            np.asarray(ref.aux["delta_eps_history_per_sample"]),
            err_msg="per-sample delta_eps diverged under padding",
        )


def test_mixed_lengths_fuse_into_one_chunk_per_bucket():
    """Distinct seq_lens inside one bucket share a fused batch and one
    compiled program; the jit cache is keyed by the ladder."""
    engine = _bucketed_engine()
    reqs = [
        SampleRequest(batch=1, seq_len=L, nfe=6, seed=10 + i)
        for i, L in enumerate([1, 3, 4, 2])  # all bucket to 4
    ]
    tickets = [engine.submit(r) for r in reqs]
    results = engine.drain(None)
    for t in tickets:
        assert results[t].padded_seq_len == 4
        assert results[t].padded_batch == 4  # one fused chunk of 4 rows
    keys = set(engine.compile_cache())
    assert len(keys) == 1
    (key,) = keys
    assert key[3] == 4 and key[5] is True  # (.., seq_bucket, dp, masked)

    # a second wave spanning both buckets: seq keys stay on the ladder
    more = [
        SampleRequest(batch=1, seq_len=L, nfe=6, seed=50 + i)
        for i, L in enumerate([2, 4, 6, 8, 5])
    ]
    tickets = [engine.submit(r) for r in more]
    results = engine.drain(None)
    assert {results[t].padded_seq_len for t in tickets} == {4, 8}
    assert {k[3] for k in engine.compile_cache()} <= set(SEQ_BUCKETS)
    compiled = len(engine.compile_cache())

    # a third wave of previously-unseen lengths that lands on the same
    # (batch bucket, seq bucket) compositions compiles nothing new — the
    # cache is bounded by the ladder, not by distinct seq_lens
    third = [
        SampleRequest(batch=1, seq_len=L, nfe=6, seed=80 + i)
        for i, L in enumerate([1, 2, 5, 6, 7, 8])
    ]
    tickets = [engine.submit(r) for r in third]
    engine.drain(None)
    assert len(engine.compile_cache()) == compiled


def test_seq_len_above_ladder_rejected_at_submit():
    engine = _bucketed_engine()
    with pytest.raises(ValueError, match="exceeds the largest seq bucket"):
        engine.submit(SampleRequest(batch=1, seq_len=9, nfe=6))
    # the async scheduler rejects at submit too (same validate path)
    sched = AsyncBatchedSampler(engine, params=None)
    with pytest.raises(ValueError, match="exceeds the largest seq bucket"):
        sched.submit(SampleRequest(batch=1, seq_len=64, nfe=6))
    sched.stop()
    # engines without a ladder accept any length
    _exact_engine().submit(SampleRequest(batch=1, seq_len=64, nfe=6))


def test_padded_seq_len_surfaced_in_results_and_facade_info():
    engine = _bucketed_engine()
    t = engine.submit(SampleRequest(batch=1, seq_len=3, nfe=6, seed=1))
    res = engine.drain(None)[t]
    assert res.padded_seq_len == 4
    assert res.padded_batch >= 1

    svc = SamplerService(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        solver_config=ERAConfig(per_sample=True),
    )
    res = svc.sample(None, SampleRequest(batch=2, seq_len=6, nfe=6))
    assert res.info[K.PADDED_SEQ_LEN] == 6  # facade runs exact-shape
    assert res.info[K.PADDED_BATCH] == 2
    assert res.x0.shape == (2, 6, OracleDenoiser.D_MODEL)


def test_unmaskable_denoiser_falls_back_to_exact_shape():
    """A denoiser that cannot guarantee masked parity serves exact-shape
    groups even when a ladder is configured."""
    dlm = OracleDenoiser(ANALYTIC)
    dlm.supports_length_masking = False
    engine = BatchedSampler(
        dlm, ANALYTIC.schedule, batch_buckets=(2, 4),
        seq_buckets=SEQ_BUCKETS,
    )
    assert engine.executor.seq_masked("era") is False
    assert engine.executor.group_key(
        SampleRequest(batch=1, seq_len=3, nfe=6)
    ) == ("era", 3, 6)
    t = engine.submit(SampleRequest(batch=1, seq_len=3, nfe=6, seed=0))
    res = engine.drain(None)[t]
    assert res.padded_seq_len == 3  # exact shape, no masking
    # the ladder still bounds accepted lengths (serving contract)
    with pytest.raises(ValueError, match="exceeds the largest seq bucket"):
        engine.submit(SampleRequest(batch=1, seq_len=99, nfe=6))


def test_non_fusable_config_falls_back_to_exact_shape():
    """Shared-delta ERA couples rows through one error norm — it cannot pad
    (rows or positions), so its traffic groups by exact seq_len."""
    engine = BatchedSampler(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        solver_config=ERAConfig(per_sample=False),
        batch_buckets=(2, 4),
        seq_buckets=SEQ_BUCKETS,
    )
    assert engine.executor.seq_masked("era") is False
    assert engine.executor.group_key(
        SampleRequest(batch=2, seq_len=5, nfe=6)
    ) == ("era", 5, 6)


def test_trajectory_aux_sliced_to_request_seq_len():
    engine = BatchedSampler(
        OracleDenoiser(ANALYTIC),
        ANALYTIC.schedule,
        solver_config=ERAConfig(per_sample=True, return_trajectory=True),
        batch_buckets=(4,),
        seq_buckets=SEQ_BUCKETS,
    )
    ta = engine.submit(SampleRequest(batch=1, seq_len=3, nfe=6, seed=0))
    tb = engine.submit(SampleRequest(batch=2, seq_len=7, nfe=6, seed=1))
    results = engine.drain(None)
    assert results[ta].aux["trajectory"].shape == (
        7, 1, 3, OracleDenoiser.D_MODEL
    )
    assert results[tb].aux["trajectory"].shape == (
        7, 2, 7, OracleDenoiser.D_MODEL
    )
    # per-sample aux keeps per-request rows only
    assert results[tb].aux["ers_selection_history"].shape[1] == 2


def test_mixed_solver_mixed_length_routing():
    """Seq bucketing composes with per-request solver routing: groups key
    on (solver, bucket, nfe), and every solver's padded run matches its
    exact-shape solo run bitwise."""
    engine = _bucketed_engine()
    reqs = [
        SampleRequest(batch=1, seq_len=L, nfe=6, solver=s, seed=500 + i)
        for i, (L, s) in enumerate(
            [(3, None), (5, "ddim"), (2, "dpm_solver_pp2m"),
             (4, "ddim"), (7, None)]
        )
    ]
    tickets = [engine.submit(r) for r in reqs]
    fused = engine.drain(None)
    for ticket, req in zip(tickets, reqs):
        ref = _solo(req)
        np.testing.assert_array_equal(
            np.asarray(fused[ticket].x0), np.asarray(ref.x0),
            err_msg=f"solver={req.solver} seq_len={req.seq_len}",
        )
    solvers_compiled = {k[0] for k in engine.compile_cache()}
    assert solvers_compiled == {"era", "ddim", "dpm_solver_pp2m"}


def test_denoiser_length_mask_parity_real_attention():
    """The DiffusionLM masking contract on a real dense-attention stack:
    valid positions of a masked padded batch reproduce the exact-shape
    eps, and pad positions come back exactly zero."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.diffusion import DiffusionLM

    cfg = get_config("llama3.2-1b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    assert dlm.supports_length_masking
    params = dlm.init(jax.random.PRNGKey(0))
    b, l_exact, l_pad = 3, 5, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (b, l_exact, cfg.d_model))
    xp = jnp.concatenate(
        [x, jnp.zeros((b, l_pad - l_exact, cfg.d_model))], axis=1
    )
    t = jnp.float32(0.7)
    e_exact = np.asarray(dlm.eps(params, x, t))
    e_mask = np.asarray(
        dlm.eps(params, xp, t, lengths=jnp.full((b,), l_exact, jnp.int32))
    )
    np.testing.assert_allclose(
        e_mask[:, :l_exact], e_exact, atol=1e-6,
        err_msg="masked padded eps diverged from exact-shape eps",
    )
    assert (e_mask[:, l_exact:] == 0.0).all()

    # SSM / MLA stacks are maskable too: directional scans are right-pad
    # prefix-safe and MLA threads the kv mask (tests/test_prefix_safety.py)
    for name in ("xlstm-350m", "hymba-1.5b", "deepseek-v2-lite-16b"):
        cfg2 = get_config(name, smoke=True)
        assert DiffusionLM(build_model(cfg2)).supports_length_masking, name


def _real_dlm_engine(arch: str):
    import jax

    from repro.configs import get_config
    from repro.core import linear_schedule
    from repro.models import build_model
    from repro.models.diffusion import DiffusionLM

    cfg = get_config(arch, smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(jax.random.PRNGKey(0))
    schedule = linear_schedule()
    engine = BatchedSampler(
        dlm, schedule, batch_buckets=(2, 4), seq_buckets=SEQ_BUCKETS
    )
    exact = BatchedSampler(dlm, schedule, batch_buckets=None)
    return engine, exact, params


@pytest.mark.parametrize("arch", ["xlstm-350m", "deepseek-v2-lite-16b"])
def test_real_denoiser_padding_invariance_wall(arch):
    """The full padding-invariance wall (x0 + per-sample ERS selections) on
    real SSM (xlstm) and MLA (deepseek-v2-lite) DiffusionLM stacks — the
    block kinds PR 5 excluded from fusion.  A mixed-length fused drain must
    match each request's exact-shape solo run at the real-denoiser parity
    bar (atol=1e-6; observed bit-identical on CPU smoke shapes), with
    bitwise-identical ERS basis selections."""
    engine, exact, params = _real_dlm_engine(arch)
    assert engine.executor.seq_masked("era") is True
    reqs = [
        SampleRequest(batch=1, seq_len=L, nfe=5, seed=700 + i)
        for i, L in enumerate([3, 8, 5])
    ]
    tickets = [engine.submit(r) for r in reqs]
    fused = engine.drain(params)
    for ticket, req in zip(tickets, reqs):
        got = fused[ticket]
        assert got.padded_seq_len == (4 if req.seq_len <= 4 else 8)
        t_ref = exact.submit(req)
        ref = exact.drain(params)[t_ref]
        np.testing.assert_allclose(
            np.asarray(got.x0), np.asarray(ref.x0), atol=1e-6,
            err_msg=f"{arch}: fused padded x0 diverged from exact-shape "
            f"solo run (seq_len={req.seq_len})",
        )
        np.testing.assert_array_equal(
            np.asarray(got.aux["ers_selection_history"]),
            np.asarray(ref.aux["ers_selection_history"]),
            err_msg=f"{arch}: ERS basis selection flipped under padding "
            f"(seq_len={req.seq_len})",
        )
    # the canary: a fully-maskable stack drains masked fused traffic with
    # zero fast-path fallbacks
    counter = engine.executor.metrics.get("sampler_masked_fallback_total")
    assert counter is not None
    assert not counter._values, dict(counter._values)


def test_masked_fallback_counter_counts_engine_fallbacks():
    """An unmaskable denoiser's exact-shape verdict increments the
    ``sampler_masked_fallback_total`` canary with the engine label."""
    dlm = OracleDenoiser(ANALYTIC)
    dlm.supports_length_masking = False
    engine = BatchedSampler(
        dlm, ANALYTIC.schedule, batch_buckets=(2, 4), seq_buckets=SEQ_BUCKETS
    )
    assert engine.executor.seq_masked("era") is False
    counter = engine.executor.metrics.get("sampler_masked_fallback_total")
    assert counter.value(impl="seq-bucketing", reason="denoiser-unmaskable") == 1
    # the verdict is cached per solver: re-asking does not re-count
    assert engine.executor.seq_masked("era") is False
    assert counter.value(impl="seq-bucketing", reason="denoiser-unmaskable") == 1


def test_mesh_mixed_length_drain_parity(mesh8):
    """Mixed-length fused drains on the 8-device mesh: bit-identical to the
    mesh exact-shape drains, and matching the single-device bucketed run
    to float tolerance (the established mesh-parity bar)."""
    reqs = [
        SampleRequest(batch=1, seq_len=L, nfe=7, seed=900 + i)
        for i, L in enumerate([2, 5, 8, 3, 6])
    ]
    mesh_engine = _bucketed_engine(mesh=mesh8)
    tickets = [mesh_engine.submit(r) for r in reqs]
    fused = mesh_engine.drain(None)
    single = _bucketed_engine()
    stickets = [single.submit(r) for r in reqs]
    sres = single.drain(None)
    for ticket, sticket, req in zip(tickets, stickets, reqs):
        ref = _solo(req, mesh=mesh8)
        np.testing.assert_array_equal(
            np.asarray(fused[ticket].x0), np.asarray(ref.x0),
            err_msg=f"mesh bucketed vs mesh exact diverged "
            f"(seq_len={req.seq_len})",
        )
        np.testing.assert_allclose(
            np.asarray(fused[ticket].x0), np.asarray(sres[sticket].x0),
            atol=1e-5,
            err_msg=f"mesh vs single-device bucketed diverged "
            f"(seq_len={req.seq_len})",
        )
