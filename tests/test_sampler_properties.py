"""Property wall around the batching engine's core invariant.

The whole fused serving path rests on one claim: a batch-of-N ERA run with
per-sample ERS equals N independent single-sample runs (paper Alg. 1 per
row).  This is what makes request fusion, bucket padding, and mesh batch
sharding all correctness-preserving.  Checked here over randomized
seq_len / nfe / k / seed via `tests/_hypothesis_compat.py` (real hypothesis
in CI, the deterministic fallback shim in bare environments).
"""

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st
from conftest import AnalyticGaussian
from repro.core import ERAConfig, get_solver

# module-level: the shim's `given` produces zero-arg tests, so no fixtures
ANALYTIC = AnalyticGaussian()
D_MODEL = 4


@settings(max_examples=5, deadline=None)
@given(
    st.integers(min_value=2, max_value=3),    # N co-batched samples
    st.integers(min_value=2, max_value=8),    # seq_len
    st.integers(min_value=2, max_value=4),    # Lagrange order k
    st.integers(min_value=0, max_value=6),    # nfe headroom above k
    st.integers(min_value=0, max_value=10_000),  # x_T seed
)
def test_batch_of_n_equals_n_single_runs(n, seq_len, k, extra, seed):
    cfg = ERAConfig(nfe=k + 1 + extra, k=k, per_sample=True)
    x = jax.random.normal(
        jax.random.PRNGKey(seed), (n, seq_len, D_MODEL), jnp.float32
    )
    era = get_solver("era")
    batched = era(ANALYTIC.eps, x, ANALYTIC.schedule, cfg)
    assert not bool(jnp.any(jnp.isnan(batched.x0)))
    for i in range(n):
        solo = era(ANALYTIC.eps, x[i : i + 1], ANALYTIC.schedule, cfg)
        np.testing.assert_allclose(
            np.asarray(batched.x0[i : i + 1]),
            np.asarray(solo.x0),
            atol=1e-5,
            err_msg=f"row {i} of batch-of-{n} diverged from its solo run "
            f"(seq_len={seq_len}, k={k}, nfe={cfg.nfe}, seed={seed})",
        )
        # the per-row ERS diagnostics must decouple the same way
        np.testing.assert_allclose(
            np.asarray(batched.aux["delta_eps_history_per_sample"][:, i]),
            np.asarray(solo.aux["delta_eps_history_per_sample"][:, 0]),
            atol=1e-4,
            err_msg=f"row {i} delta_eps history diverged",
        )
