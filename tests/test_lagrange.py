import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.lagrange import (
    ers_select,
    fixed_select,
    interpolate,
    lagrange_weights,
)


def test_weights_partition_of_unity():
    t = jnp.array([0.9, 0.7, 0.4, 0.1])
    w = lagrange_weights(t, 0.25)
    assert abs(float(jnp.sum(w)) - 1.0) < 1e-5


def test_weights_at_nodes():
    t = jnp.array([0.9, 0.7, 0.4, 0.1])
    for i in range(4):
        w = np.asarray(lagrange_weights(t, t[i]))
        expect = np.zeros(4)
        expect[i] = 1.0
        np.testing.assert_allclose(w, expect, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.floats(0.01, 1.0), min_size=3, max_size=5, unique=True
    ).map(sorted),
    st.floats(-2.0, 2.0),
    st.floats(-2.0, 2.0),
    st.floats(-2.0, 2.0),
)
def test_interpolation_exact_on_polynomials(nodes, c0, c1, c2):
    """Degree<=k-1 polynomials are reproduced exactly (hypothesis)."""
    t = jnp.asarray(nodes, jnp.float32)
    poly = lambda x: c0 + c1 * x + c2 * x * x
    values = poly(t)[:, None]          # (k, 1) "eps" values
    t_eval = 0.5 * (nodes[0] + nodes[-1]) - 0.3
    got = interpolate(values, t, jnp.float32(t_eval))
    assert abs(float(got[0]) - float(poly(jnp.float32(t_eval)))) < 1e-2


@settings(max_examples=50, deadline=None)
@given(st.integers(3, 40), st.integers(2, 6), st.floats(0.01, 20.0))
def test_ers_select_invariants(i, k, power):
    """Indices are strictly increasing, within [0, i] (any error power)."""
    if i < k:
        return
    tau = np.asarray(ers_select(jnp.int32(i), k, jnp.float32(power)))
    assert tau.shape == (k,)
    assert np.all(np.diff(tau) >= 1), tau
    assert tau[0] >= 0 and tau[-1] <= i


def test_ers_uniform_at_power_one():
    """Power 1 (delta_eps == lambda init) -> uniform coverage incl. latest."""
    tau = np.asarray(ers_select(jnp.int32(12), 4, jnp.float32(1.0)))
    np.testing.assert_array_equal(tau, [3, 6, 9, 12])


def test_ers_biases_early_when_error_high():
    """Large measured error (power >> 1) pushes bases toward the early,
    more accurate, part of the buffer (paper Fig. 3)."""
    lo = np.asarray(ers_select(jnp.int32(20), 4, jnp.float32(1.0)))
    hi = np.asarray(ers_select(jnp.int32(20), 4, jnp.float32(6.0)))
    assert np.sum(hi[:-1]) < np.sum(lo[:-1])


def test_fixed_select_last_k():
    tau = np.asarray(fixed_select(jnp.int32(10), 3))
    np.testing.assert_array_equal(tau, [8, 9, 10])
