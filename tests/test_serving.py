import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ERAConfig, linear_schedule
from repro.models import build_model
from repro.models.diffusion import DiffusionLM
from repro.serving import (
    Engine,
    SampleRequest,
    SamplerService,
    ServeConfig,
    cache_slots,
    resolve_window,
    result_keys as K,
)

KEY = jax.random.PRNGKey(0)


def test_generate_basic():
    cfg = get_config("llama3.2-1b", smoke=True)
    m = build_model(cfg)
    eng = Engine(m, ServeConfig(max_len=128))
    params = m.init(KEY)
    prompts = jax.random.randint(KEY, (2, 10), 0, cfg.vocab_size)
    toks = eng.generate(params, prompts, 6)
    assert toks.shape == (2, 6)
    assert int(jnp.max(toks)) < cfg.vocab_size


def test_greedy_deterministic():
    cfg = get_config("qwen2-1.5b", smoke=True)
    m = build_model(cfg)
    eng = Engine(m, ServeConfig(max_len=64))
    params = m.init(KEY)
    prompts = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    a = eng.generate(params, prompts, 5, key=jax.random.PRNGKey(1))
    b = eng.generate(params, prompts, 5, key=jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_windowed_decode_matches_full_within_window():
    """With prompt+gen <= window, ring-buffer decode == full attention."""
    cfg = get_config("llama3.2-1b", smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    prompts = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    full = Engine(m, ServeConfig(max_len=64)).generate(params, prompts, 6)
    ring = Engine(m, ServeConfig(max_len=64, window_override=32)).generate(
        params, prompts, 6
    )
    np.testing.assert_array_equal(np.asarray(full), np.asarray(ring))


def test_long_decode_beyond_window_runs():
    cfg = get_config("minitron-4b", smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    eng = Engine(m, ServeConfig(max_len=512, window_override=16))
    prompts = jax.random.randint(KEY, (1, 48), 0, cfg.vocab_size)
    toks = eng.generate(params, prompts, 40)  # far beyond the 16-slot ring
    assert toks.shape == (1, 40)


def test_cache_slots_policy():
    cfg = get_config("mixtral-8x7b")          # native SWA 4096
    assert cache_slots(cfg, ServeConfig(max_len=100000)) == 4096
    dense = get_config("deepseek-67b")
    assert cache_slots(dense, ServeConfig(max_len=4096)) == 4096
    assert resolve_window(dense, ServeConfig(), 524288) == dense.long_context_window
    assert resolve_window(cfg, ServeConfig(), 4096) == -1


def test_engine_generate_on_mesh_matches_single_device(mesh8):
    """Data-parallel generate (params replicated, batch sharded) is token-
    identical to the single-device engine; a non-divisible batch silently
    degrades to replicated."""
    cfg = get_config("llama3.2-1b", smoke=True)
    m = build_model(cfg)
    params = m.init(KEY)
    prompts = jax.random.randint(KEY, (8, 10), 0, cfg.vocab_size)
    single = Engine(m, ServeConfig(max_len=64)).generate(params, prompts, 4)
    meshed = Engine(m, ServeConfig(max_len=64), mesh=mesh8)
    toks = meshed.generate(params, prompts, 4)
    np.testing.assert_array_equal(np.asarray(single), np.asarray(toks))
    toks3 = meshed.generate(params, prompts[:3], 4)
    np.testing.assert_array_equal(np.asarray(single)[:3], np.asarray(toks3))


def test_sampler_service_solver_choice():
    cfg = get_config("qwen2-1.5b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(KEY)
    sched = linear_schedule()
    outs = {}
    for solver in ("ddim", "era"):
        sc = ERAConfig(nfe=6, k=3) if solver == "era" else None
        svc = SamplerService(dlm, sched, solver, sc)
        x0 = svc.sample(params, SampleRequest(batch=2, seq_len=8, nfe=6)).x0
        assert x0.shape == (2, 8, cfg.d_model)
        assert not bool(jnp.any(jnp.isnan(x0)))
        outs[solver] = np.asarray(x0)
    assert np.max(np.abs(outs["ddim"] - outs["era"])) > 1e-6  # different paths


def test_sampler_service_surfaces_engine_telemetry():
    """The facade's info dict carries the same telemetry as the engine's
    SampleResult: latency_s and padded_batch, not just wall_s + aux."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    params = dlm.init(KEY)
    svc = SamplerService(dlm, linear_schedule(), "era", ERAConfig(nfe=6, k=3))
    res = svc.sample(params, SampleRequest(batch=2, seq_len=8, nfe=6))
    info = res.info
    assert info[K.PADDED_BATCH] == 2  # exact-size facade buckets
    assert info[K.LATENCY_S] >= info[K.WALL_S] > 0
    assert K.DELTA_EPS_HISTORY in info
    # the pre-unification tuple unpacking still works, with a warning
    with pytest.warns(DeprecationWarning, match="tuple unpacking"):
        x0, info2 = res
    assert x0 is res.x0 and set(info2) == set(info)


def test_sample_program_lowerable():
    """The whole ERA sampling loop lowers as one XLA program."""
    cfg = get_config("llama3.2-1b", smoke=True)
    dlm = DiffusionLM(build_model(cfg))
    svc = SamplerService(dlm, linear_schedule(), "era", ERAConfig(nfe=6, k=3))
    prog = svc.sample_program()
    aparams = dlm.init_abstract()
    x = jax.ShapeDtypeStruct((2, 8, cfg.d_model), jnp.float32)
    lowered = jax.jit(prog).lower(aparams, x)
    assert lowered.compile() is not None
