"""Sharding-rule correctness (pure pspec logic — no devices needed) and the
dry-run plumbing (subprocess with placeholder devices, marked slow)."""

import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, arch_names, get_config
from repro.launch.specs import build_program, train_microbatches
from repro.models import build_model
from repro.parallel.sharding import ShardingRules


class FakeMesh:
    """Just enough Mesh surface for pspec derivation."""

    def __init__(self, shape: dict):
        self._shape = shape

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return self._shape


def rules_for(name, multi=False, fsdp=False):
    shape = (
        {"pod": 2, "data": 16, "model": 16} if multi else {"data": 16, "model": 16}
    )
    return ShardingRules(get_config(name), FakeMesh(shape), fsdp=fsdp)


def _leaves_with_paths(tree):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        yield "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path), leaf


@pytest.mark.parametrize("name", arch_names())
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible(name, fsdp):
    """Every sharded dim must divide by its mesh axes (else jit rejects)."""
    rules = rules_for(name, fsdp=fsdp)
    model = build_model(get_config(name))
    aparams = model.init_abstract()
    specs = rules.param_pspec(aparams)
    mesh_shape = {"data": 16, "model": 16}
    for (path, leaf), (_, spec) in zip(
        _leaves_with_paths(aparams), _leaves_with_paths(specs)
    ):
        for dim, part in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if part is None:
                continue
            parts = (part,) if isinstance(part, str) else part
            total = 1
            for ax in parts:
                total *= mesh_shape[ax]
            assert dim % total == 0, (name, path, leaf.shape, spec)


def test_tensor_parallel_actually_used():
    rules = rules_for("llama3.2-1b")
    model = build_model(get_config("llama3.2-1b"))
    specs = rules.param_pspec(model.init_abstract())
    flat = dict(_leaves_with_paths(specs))
    assert flat["segs/0_dense/mlp/wi/w"] == P(None, None, "model")
    assert flat["segs/0_dense/mlp/wo/w"] == P(None, "model", None)
    assert flat["embed"] == P("model", None)


def test_expert_parallel_for_deepseek():
    rules = rules_for("deepseek-v2-lite-16b")
    model = build_model(get_config("deepseek-v2-lite-16b"))
    specs = rules.param_pspec(model.init_abstract())
    flat = dict(_leaves_with_paths(specs))
    # 64 experts / 16 shards -> expert-parallel
    assert flat["segs/0_mla_moe/moe/experts/wi"] == P(None, "model", None, None)


def test_mixtral_experts_tensor_parallel():
    rules = rules_for("mixtral-8x7b")
    model = build_model(get_config("mixtral-8x7b"))
    specs = rules.param_pspec(model.init_abstract())
    flat = dict(_leaves_with_paths(specs))
    # 8 experts don't divide 16 -> ff-dim tensor parallel
    assert flat["segs/0_moe/moe/experts/wi"] == P(None, None, None, "model")
    assert flat["segs/0_moe/moe/experts/wo"] == P(None, None, "model", None)


def test_fsdp_excludes_embeddings():
    rules = rules_for("deepseek-67b", fsdp=True)
    model = build_model(get_config("deepseek-67b"))
    specs = rules.param_pspec(model.init_abstract())
    flat = dict(_leaves_with_paths(specs))
    assert "data" not in str(flat["embed"])
    assert "data" in str(flat["segs/0_dense/mlp/wi/w"])


def test_microbatch_heuristic():
    cfg = get_config("deepseek-67b")
    assert train_microbatches(cfg, INPUT_SHAPES["train_4k"], dp=16) == 16
    small = get_config("whisper-base")
    assert train_microbatches(small, INPUT_SHAPES["train_4k"], dp=16) == 1


@pytest.mark.parametrize("name", arch_names())
def test_programs_build_for_all_shapes(name):
    """Abstract programs assemble for all 4 input shapes (no allocation)."""
    model = build_model(get_config(name))
    for shape in INPUT_SHAPES.values():
        prog = build_program(model, shape)
        assert prog.args, (name, shape.name)


@pytest.mark.slow
def test_dryrun_subprocess_single_combo(tmp_path):
    """Real 512-placeholder-device lower+compile of one combo."""
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "llama3.2-1b", "--shape", "decode_32k",
            "--mesh", "multi", "--out", str(tmp_path),
        ],
        capture_output=True, text=True, timeout=600, cwd="/root/repo", env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(
        (tmp_path / "llama3.2-1b__decode_32k__multi.json").read_text()
    )
    assert rec["ok"] and rec["num_devices"] == 512
    assert rec["hlo"]["flops"] > 0
