"""Sharding-rule correctness (pure pspec logic — no devices needed), the
sampling-engine carry specs, mesh-sharded drain placement (8-virtual-device
fixture), and the dry-run plumbing (subprocess, marked slow)."""

import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from conftest import OracleDenoiser
from repro.configs import INPUT_SHAPES, arch_names, get_config
from repro.launch.specs import build_program, train_microbatches
from repro.models import build_model
from repro.parallel.sharding import (
    ParamReplicator,
    ShardingRules,
    round_to_dp,
    sampler_pspecs,
    sampler_shardings,
)


class FakeMesh:
    """Just enough Mesh surface for pspec derivation."""

    def __init__(self, shape: dict):
        self._shape = shape

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return self._shape


def rules_for(name, multi=False, fsdp=False):
    shape = (
        {"pod": 2, "data": 16, "model": 16} if multi else {"data": 16, "model": 16}
    )
    return ShardingRules(get_config(name), FakeMesh(shape), fsdp=fsdp)


def _leaves_with_paths(tree):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        yield "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path), leaf


@pytest.mark.parametrize("name", arch_names())
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible(name, fsdp):
    """Every sharded dim must divide by its mesh axes (else jit rejects)."""
    rules = rules_for(name, fsdp=fsdp)
    model = build_model(get_config(name))
    aparams = model.init_abstract()
    specs = rules.param_pspec(aparams)
    mesh_shape = {"data": 16, "model": 16}
    for (path, leaf), (_, spec) in zip(
        _leaves_with_paths(aparams), _leaves_with_paths(specs)
    ):
        for dim, part in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if part is None:
                continue
            parts = (part,) if isinstance(part, str) else part
            total = 1
            for ax in parts:
                total *= mesh_shape[ax]
            assert dim % total == 0, (name, path, leaf.shape, spec)


def test_tensor_parallel_actually_used():
    rules = rules_for("llama3.2-1b")
    model = build_model(get_config("llama3.2-1b"))
    specs = rules.param_pspec(model.init_abstract())
    flat = dict(_leaves_with_paths(specs))
    assert flat["segs/0_dense/mlp/wi/w"] == P(None, None, "model")
    assert flat["segs/0_dense/mlp/wo/w"] == P(None, "model", None)
    assert flat["embed"] == P("model", None)


def test_expert_parallel_for_deepseek():
    rules = rules_for("deepseek-v2-lite-16b")
    model = build_model(get_config("deepseek-v2-lite-16b"))
    specs = rules.param_pspec(model.init_abstract())
    flat = dict(_leaves_with_paths(specs))
    # 64 experts / 16 shards -> expert-parallel
    assert flat["segs/0_mla_moe/moe/experts/wi"] == P(None, "model", None, None)


def test_mixtral_experts_tensor_parallel():
    rules = rules_for("mixtral-8x7b")
    model = build_model(get_config("mixtral-8x7b"))
    specs = rules.param_pspec(model.init_abstract())
    flat = dict(_leaves_with_paths(specs))
    # 8 experts don't divide 16 -> ff-dim tensor parallel
    assert flat["segs/0_moe/moe/experts/wi"] == P(None, None, None, "model")
    assert flat["segs/0_moe/moe/experts/wo"] == P(None, None, "model", None)


def test_fsdp_excludes_embeddings():
    rules = rules_for("deepseek-67b", fsdp=True)
    model = build_model(get_config("deepseek-67b"))
    specs = rules.param_pspec(model.init_abstract())
    flat = dict(_leaves_with_paths(specs))
    assert "data" not in str(flat["embed"])
    assert "data" in str(flat["segs/0_dense/mlp/wi/w"])


# ---------------------------------------------------------------------------
# sampling-engine carry specs (pure pspec logic)
# ---------------------------------------------------------------------------


def test_sampler_pspecs_batch_sharded_carry():
    """Latents/eps buffer shard the batch dim over the data axes; the time
    grid replicates; per-sample delta_eps follows the batch."""
    specs = sampler_pspecs(FakeMesh({"data": 8}), batch=16, per_sample=True)
    assert specs.x == P(("data",), None, None)
    assert specs.eps_buf == P(None, ("data",), None, None)
    assert specs.t_buf == P()
    assert specs.delta_eps == P(("data",))


def test_sampler_pspecs_multi_pod_and_shared_delta():
    mesh = FakeMesh({"pod": 2, "data": 8, "model": 2})
    specs = sampler_pspecs(mesh, batch=16, per_sample=False)
    assert specs.x == P(("pod", "data"), None, None)
    assert specs.delta_eps == P()  # shared scalar delta replicates


def test_sampler_pspecs_non_divisible_batch_replicates():
    """An exact-size (unpadded) batch that doesn't divide dp must degrade to
    replicated specs, never a ragged-shard error."""
    specs = sampler_pspecs(FakeMesh({"data": 8}), batch=3, per_sample=True)
    assert specs.x == P(None, None, None)
    assert specs.eps_buf == P(None, None, None, None)
    assert specs.delta_eps == P(None)


def test_round_to_dp():
    mesh = FakeMesh({"data": 8})
    assert round_to_dp(1, mesh) == 8
    assert round_to_dp(8, mesh) == 8
    assert round_to_dp(9, mesh) == 16
    assert round_to_dp(5, None) == 5


def test_solver_program_carry_pspecs():
    """PR-4: carry pspecs derive from the program's declared state — ERA's
    per-sample ERS shards delta_eps with its rows, shared-delta ERA and
    every baseline replicate it; the rest of the carry is the shared
    batch-over-data-axes layout."""
    from repro.core import ERAConfig, default_config, get_program
    from repro.parallel.sharding import solver_carry_pspecs

    mesh = FakeMesh({"data": 8})
    era = get_program("era")
    specs = solver_carry_pspecs(mesh, era, ERAConfig(per_sample=True), batch=16)
    assert specs.delta_eps == P(("data",))
    assert specs.eps_buf == P(None, ("data",), None, None)
    specs = solver_carry_pspecs(mesh, era, ERAConfig(), batch=16)
    assert specs.delta_eps == P()  # shared scalar delta replicates
    for name in ("ddim", "explicit_adams", "dpm_solver_pp2m"):
        program = get_program(name)
        cfg = default_config(name)
        assert not program.per_sample_state(cfg)
        specs = program.carry_pspecs(cfg, mesh, batch=16)
        assert specs.x == P(("data",), None, None)
        assert specs.t_buf == P()


def test_param_replicator_invalidates_on_leaf_change():
    """The placement cache keys on leaf identity, so mutating the params
    container in place (finetune-and-sample loop) gets fresh weights instead
    of the first call's stale copy.  Works on any device count (a 1-device
    mesh replicates trivially)."""
    import jax.numpy as jnp

    from repro.launch.mesh import make_sampler_mesh

    rep = ParamReplicator(make_sampler_mesh(1))
    params = {"w": jnp.ones((4,)), "b": jnp.zeros((2,))}
    first = rep(params)
    assert rep(params) is first                   # same leaves -> cached
    params["w"] = jnp.full((4,), 2.0)             # in-place container mutation
    second = rep(params)
    assert second is not first
    assert float(second["w"][0]) == 2.0


# ---------------------------------------------------------------------------
# mesh-sharded drain placement (8-virtual-device fixture; the CI sharded job
# runs these in-process, single-device runs cover parity via the subprocess
# test in test_batched_sampler.py)
# ---------------------------------------------------------------------------


def test_sampler_shardings_on_real_mesh(mesh8):
    sh = sampler_shardings(mesh8, batch=8, per_sample=True)
    assert sh.x.spec == P(("data",), None, None)
    assert len(sh.x.mesh.devices.ravel()) == 8


def test_mesh_drain_places_rows_across_devices(mesh8, analytic):
    from repro.serving import BatchedSampler, SampleRequest

    eng = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, mesh=mesh8
    )
    assert eng.dp == 8
    t = eng.submit(SampleRequest(batch=8, seq_len=6, nfe=6, seed=0))
    res = eng.drain(params=None)[t]
    assert res.padded_batch == 8
    # one row per device: the drain really ran data-parallel
    assert len(res.x0.sharding.device_set) == 8
    solo = BatchedSampler(
        OracleDenoiser(analytic), analytic.schedule, batch_buckets=None
    )
    t2 = solo.submit(SampleRequest(batch=8, seq_len=6, nfe=6, seed=0))
    import numpy as np

    np.testing.assert_allclose(
        np.asarray(res.x0),
        np.asarray(solo.drain(params=None)[t2].x0),
        atol=1e-5,
    )


def test_microbatch_heuristic():
    cfg = get_config("deepseek-67b")
    assert train_microbatches(cfg, INPUT_SHAPES["train_4k"], dp=16) == 16
    small = get_config("whisper-base")
    assert train_microbatches(small, INPUT_SHAPES["train_4k"], dp=16) == 1


@pytest.mark.parametrize("name", arch_names())
def test_programs_build_for_all_shapes(name):
    """Abstract programs assemble for all 4 input shapes (no allocation)."""
    model = build_model(get_config(name))
    for shape in INPUT_SHAPES.values():
        prog = build_program(model, shape)
        assert prog.args, (name, shape.name)


@pytest.mark.slow
def test_dryrun_subprocess_single_combo(tmp_path):
    """Real 512-placeholder-device lower+compile of one combo."""
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "llama3.2-1b", "--shape", "decode_32k",
            "--mesh", "multi", "--out", str(tmp_path),
        ],
        capture_output=True, text=True, timeout=600, cwd="/root/repo", env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(
        (tmp_path / "llama3.2-1b__decode_32k__multi.json").read_text()
    )
    assert rec["ok"] and rec["num_devices"] == 512
    assert rec["hlo"]["flops"] > 0
