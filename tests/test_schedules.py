import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import cosine_schedule, linear_schedule, timesteps

SCHEDULES = [linear_schedule(), cosine_schedule()]


@pytest.mark.parametrize("sched", SCHEDULES, ids=lambda s: s.name)
def test_vp_identity(sched):
    t = jnp.linspace(1e-4, 1.0, 101)
    a, s = sched.alpha(t), sched.sigma(t)
    np.testing.assert_allclose(a * a + s * s, 1.0, atol=1e-5)


@pytest.mark.parametrize("sched", SCHEDULES, ids=lambda s: s.name)
def test_monotone(sched):
    t = jnp.linspace(1e-4, 1.0, 200)
    assert np.all(np.diff(np.asarray(sched.alpha(t))) <= 1e-6)
    assert np.all(np.diff(np.asarray(sched.sigma(t))) >= -1e-6)
    assert np.all(np.diff(np.asarray(sched.lam(t))) < 0)


@settings(max_examples=25, deadline=None)
@given(st.floats(1e-3, 0.999))
def test_linear_inv_lam_roundtrip(t):
    sched = linear_schedule()
    lam = sched.lam(jnp.float32(t))
    t2 = sched.inv_lam(lam)
    assert abs(float(t2) - t) < 1e-3


def test_cosine_inv_lam_bisection():
    sched = cosine_schedule()
    for t in (0.05, 0.3, 0.9):
        lam = sched.lam(jnp.float32(t))
        assert abs(float(sched.inv_lam(lam)) - t) < 1e-3


@pytest.mark.parametrize("scheme", ["uniform", "quadratic", "logsnr"])
def test_timestep_grids(scheme):
    sched = linear_schedule()
    ts = np.asarray(timesteps(sched, 17, scheme))
    assert ts.shape == (18,)
    assert abs(ts[0] - sched.t_begin) < 1e-5
    assert abs(ts[-1] - sched.t_end) < 1e-5
    assert np.all(np.diff(ts) < 0), "grid must be strictly decreasing"


def test_ddim_coeffs_endpoint():
    sched = linear_schedule()
    # at t==t' update is the identity
    cx, ce = sched.ddim_coeffs(jnp.float32(0.5), jnp.float32(0.5))
    assert abs(float(cx) - 1.0) < 1e-6 and abs(float(ce)) < 1e-6


def test_discrete_adapter():
    sched = linear_schedule(num_train_steps=1000)
    assert int(sched.discrete_t(jnp.float32(1.0))) == 999
    assert int(sched.discrete_t(jnp.float32(1e-4))) == 0
