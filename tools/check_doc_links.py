"""Docs link checker: fail on dead relative links in README.md and docs/.

CI runs this (see .github/workflows/ci.yml, lint job) so the docs book can
cross-reference files — other docs pages, source modules, benchmarks —
without links rotting as the tree is refactored.

Checked: inline markdown links/images ``[text](target)`` whose target is
relative (no scheme, no leading ``#``).  A ``path#anchor`` target checks
the path.  External (``http(s)://``, ``mailto:``) and pure in-page anchor
links are skipped.  Link targets are resolved against the linking file's
directory and must exist inside the repo.

Usage: ``python tools/check_doc_links.py [root]`` (default: repo root).
Exits non-zero listing every dead link.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links and images; [text](target "title") titles are stripped
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files(root: Path) -> list[Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def dead_links(root: Path) -> list[str]:
    failures = []
    for md in doc_files(root):
        text = md.read_text(encoding="utf-8")
        for match in LINK_RE.finditer(text):
            target = match.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:  # pure in-page anchor
                continue
            # root-relative targets (GitHub-style /docs/x.md) resolve
            # against the repo root, others against the linking file
            base = root if path.startswith("/") else md.parent
            resolved = (base / path.lstrip("/")).resolve()
            line = text[: match.start()].count("\n") + 1
            rel = md.relative_to(root)
            if not resolved.is_relative_to(root):
                failures.append(
                    f"{rel}:{line}: link escapes the repo -> {target}"
                )
            elif not resolved.exists():
                failures.append(f"{rel}:{line}: dead link -> {target}")
    return failures


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).parents[1]
    root = root.resolve()
    failures = dead_links(root)
    for f in failures:
        print(f, file=sys.stderr)
    checked = len(doc_files(root))
    if failures:
        print(
            f"FAILED: {len(failures)} dead link(s) across {checked} doc "
            f"file(s)",
            file=sys.stderr,
        )
        return 1
    print(f"ok: no dead relative links across {checked} doc file(s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
